#!/usr/bin/env python3
"""Markdown link checker: every relative link in tracked *.md must resolve.

Network-free by design (CI runs it on every PR): external http(s)/mailto
links are skipped; relative links — with optional #fragments — are resolved
against the file's directory and must point at an existing file or
directory inside the repo.

Usage: python tools/check_md_links.py [root]   (default: repo root)
"""
from __future__ import annotations

import re
import subprocess
import sys
from pathlib import Path

# [text](target) — tolerating one level of nested [] in the text part
LINK = re.compile(r"\[(?:[^\[\]]|\[[^\]]*\])*\]\(([^)\s]+)(?:\s+\"[^\"]*\")?\)")
SKIP_DIRS = {"__pycache__", "node_modules", "venv", "env", "site-packages"}


def _skipped(parts) -> bool:
    # hidden dirs (.git, .venv, .tox, ...) and third-party trees
    return any(p in SKIP_DIRS or p.startswith(".") for p in parts)


def iter_md(root: Path):
    """Tracked *.md when root is a git checkout; filtered rglob otherwise."""
    try:
        out = subprocess.run(
            ["git", "-C", str(root), "ls-files", "--", "*.md"],
            capture_output=True, text=True, timeout=30)
        if out.returncode == 0:
            for rel in sorted(out.stdout.splitlines()):
                p = root / rel
                if rel and p.exists():   # staged deletions
                    yield p
            return
    except (OSError, subprocess.TimeoutExpired):
        pass
    for p in sorted(root.rglob("*.md")):
        if not _skipped(p.relative_to(root).parent.parts):
            yield p


def check(root: Path, counter: list | None = None) -> list[str]:
    errors = []
    for md in iter_md(root):
        if counter is not None:
            counter.append(md)
        text = md.read_text(encoding="utf-8")
        # code routinely contains pseudo-links; drop fenced blocks and
        # inline spans before matching
        text = re.sub(r"```.*?```", "", text, flags=re.S)
        text = re.sub(r"`[^`\n]*`", "", text)
        for m in LINK.finditer(text):
            target = m.group(1)
            if target.startswith(("http://", "https://", "mailto:", "#")):
                continue
            path = target.split("#", 1)[0]
            if not path:
                continue
            resolved = (md.parent / path).resolve()
            try:
                resolved.relative_to(root.resolve())
            except ValueError:
                errors.append(f"{md.relative_to(root)}: link escapes repo: "
                              f"{target}")
                continue
            if not resolved.exists():
                errors.append(f"{md.relative_to(root)}: broken link: "
                              f"{target}")
    return errors


def main() -> int:
    root = Path(sys.argv[1]) if len(sys.argv) > 1 else \
        Path(__file__).resolve().parent.parent
    seen: list = []
    errors = check(root, counter=seen)
    for e in errors:
        print(e, file=sys.stderr)
    print(f"checked {len(seen)} markdown files: "
          f"{'OK' if not errors else f'{len(errors)} broken links'}")
    return 1 if errors else 0


if __name__ == "__main__":
    sys.exit(main())
