"""Quickstart: losslessly summarize a dynamic graph stream with MoSSo.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

from repro.core.reference import MoSSo
from repro.graph.streams import (edges_to_fully_dynamic_stream, sbm_edges)

# 1. a fully dynamic stream: insertions + deletions (Sect. 2.1)
edges = sbm_edges(60, 4, 0.6, 0.02, seed=1)
stream = edges_to_fully_dynamic_stream(edges, delete_prob=0.1, seed=2)
print(f"stream: {len(stream)} changes "
      f"({sum(1 for c in stream if not c[2])} deletions)")

# 2. incremental lossless summarization (Alg. 1)
algo = MoSSo(seed=0, c=40, escape=0.2)
algo.run(stream)

print(f"phi = |P|+|C+|+|C-| = {algo.s.phi}  vs  |E| = {algo.s.num_edges}")
print(f"compression ratio (Eq. 3): {algo.s.compression_ratio():.3f}")
print(f"trials: {algo.stats.trials}, accepted: {algo.stats.accepted}, "
      f"escapes: {algo.stats.escapes}")

# 3. the summary is queryable (Lemma 1): neighborhoods straight from (G*, C)
some_node = next(iter(algo.s.n2s))
print(f"N({some_node}) from the summary: {sorted(algo.s.neighbors(some_node))}")

# 4. and lossless: decoding recovers the exact current snapshot
out = algo.s.materialize()
decoded = out.decode_edges()
truth = set()
for (u, v, ins) in stream:
    e = (min(u, v), max(u, v))
    truth.add(e) if ins else truth.discard(e)
assert decoded == truth, "lossless decoding failed!"
print(f"decoded {len(decoded)} edges == ground truth: lossless ✓")
print(f"summary graph: {len(out.supernodes)} supernodes, "
      f"{len(out.superedges)} superedges, |C+|={len(out.c_plus)}, "
      f"|C-|={len(out.c_minus)}")
