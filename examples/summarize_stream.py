"""End-to-end driver: batched-engine summarization of a large stream with
fault-tolerant checkpointing (the paper's workload, production shape).

Feeds a ~50k-change fully dynamic stream through the jitted Tier-B engine,
reports the any-time compression ratio as the graph evolves, checkpoints
engine state mid-stream, simulates a crash, restores, and verifies the
restored run ends at the identical state.

Run:  PYTHONPATH=src python examples/summarize_stream.py [n_nodes]
"""
import sys
import time
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

import jax
import numpy as np

from repro.checkpoint import checkpointer
from repro.core.engine import BatchedSummarizer, EngineConfig
from repro.graph.streams import (barabasi_albert_edges,
                                 edges_to_fully_dynamic_stream)

n_nodes = int(sys.argv[1]) if len(sys.argv) > 1 else 3000
edges = barabasi_albert_edges(n_nodes, 4, seed=0)
stream = edges_to_fully_dynamic_stream(edges, delete_prob=0.1, seed=1)
print(f"stream: {len(stream)} changes over {n_nodes} nodes")

cfg = EngineConfig(n_cap=1 << max(8, (2 * n_nodes).bit_length()),
                   m_cap=1 << max(10, (2 * len(stream)).bit_length()),
                   d_cap=64, sn_cap=48, c=24, batch=64, escape=0.2)
bs = BatchedSummarizer(cfg)

ckpt_dir = "/tmp/mosso_stream_ckpt"
half = len(stream) // 2
t0 = time.time()
bs.process(stream[:half])
t_half = time.time() - t0
print(f"[t={half}] ratio={bs.compression_ratio():.3f} phi={bs.phi} "
      f"({1e6*t_half/half:.0f} us/change incl. compile)")

# --- fault tolerance: checkpoint, 'crash', restore, continue -------------
checkpointer.save(ckpt_dir, half, bs.state._asdict(),
                  extra={"stream_cursor": half})
print(f"checkpointed engine state at change {half}")

bs2 = BatchedSummarizer(cfg)                     # fresh process after crash
restored = checkpointer.restore(ckpt_dir, half, bs2.state._asdict())
bs2.state = type(bs2.state)(**restored)
bs2._ids = dict(bs._ids)                          # id map travels in meta
bs2._rev = list(bs._rev)
cursor = checkpointer.load_meta(ckpt_dir, half)["extra"]["stream_cursor"]

t0 = time.time()
bs.process(stream[half:])
bs2.process(stream[cursor:])
t_rest = time.time() - t0
assert bs.phi == bs2.phi, "restored run diverged!"
print(f"crash-restore verified: both runs end at phi={bs.phi} ✓")

print(f"[t={len(stream)}] ratio={bs.compression_ratio():.3f} "
      f"phi={bs.phi} |E|={bs.num_edges}")
print(f"stats: {bs.stats()}")
print(f"steady-state throughput: "
      f"{(len(stream)-half)/t_rest*2:.0f} changes/s on CPU "
      f"(both runs; TPU is the deployment target)")
