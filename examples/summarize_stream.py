"""End-to-end driver: sharded, device-routed summarization of a large
stream with fault-tolerant checkpointing (the paper's workload, production
shape).

Feeds a fully dynamic stream through ``ShardedSummarizer`` on the default
``routing="device"`` path — the two-stage pipelined router that hashes
labels on the host (no per-change dict work), routes and interns on
device, and overlaps chunk k+1's routing with chunk k's engine rounds —
then reports the any-time compression ratio, certifies the sync-free
dispatch telemetry, checkpoints the device state mid-stream, simulates a
crash, restores, and verifies the restored run ends at the identical
state.

This example is CI-smoked (`.github/workflows/ci.yml`), so it cannot
drift from the real API.

Run:  PYTHONPATH=src python examples/summarize_stream.py [n_nodes] \
          [--proposal {minhash,magsdm}] [--objective {exact,weighted}]
"""
import argparse
import sys
import time
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

from repro.checkpoint import checkpointer
from repro.core.engine import EngineConfig, ShardedSummarizer
from repro.core.engine.state import OBJECTIVES, PROPOSALS
from repro.dist.router import DEFAULT_REPLICA_EXEC
from repro.graph.streams import (barabasi_albert_edges,
                                 edges_to_fully_dynamic_stream)

# policy defaults come FROM EngineConfig so this example cannot drift from
# the engine (same contract as repro/launch/stream.py)
_dflt = EngineConfig()
ap = argparse.ArgumentParser()
ap.add_argument("n_nodes", type=int, nargs="?", default=2000)
ap.add_argument("--proposal", choices=list(PROPOSALS), default=_dflt.proposal)
ap.add_argument("--objective", choices=list(OBJECTIVES),
                default=_dflt.objective)
ap.add_argument("--weight-levels", type=int, default=_dflt.weight_levels)
args = ap.parse_args()

n_nodes = args.n_nodes
edges = barabasi_albert_edges(n_nodes, 4, seed=0)
stream = edges_to_fully_dynamic_stream(edges, delete_prob=0.1, seed=1)
print(f"stream: {len(stream)} changes over {n_nodes} nodes")

# per-shard caps budget the vertex-cut replication factor, not |V|/n_shards
# (src/repro/dist/README.md)
cfg = EngineConfig(n_cap=1 << max(8, (2 * n_nodes).bit_length()),
                   m_cap=1 << max(10, (2 * len(stream)).bit_length()),
                   d_cap=64, sn_cap=48, c=24, batch=64, escape=0.2,
                   proposal=args.proposal, objective=args.objective,
                   weight_levels=args.weight_levels)
print(f"policy: proposal={cfg.proposal} objective={cfg.objective} "
      f"commit={cfg.commit}")
ss = ShardedSummarizer(cfg, n_shards=2, router_chunk=512)
assert ss.routing == "device" and ss.sync_free and ss.pipeline
# the constructor resolves replica_exec=None to the backend-aware default
assert ss.replica_exec == DEFAULT_REPLICA_EXEC
print(f"router: chunk={ss.router_chunk} lane_cap={ss.lane_cap} "
      f"sync_free={ss.sync_free} pipeline={ss.pipeline} "
      f"replica_exec={ss.replica_exec}")

ckpt_dir = "/tmp/mosso_stream_ckpt"
half = (len(stream) // 2 // ss.router_chunk) * ss.router_chunk
t0 = time.time()
ss.process(stream[:half])
t_half = time.time() - t0
print(f"[t={half}] ratio={ss.compression_ratio():.3f} phi={ss.phi} "
      f"({1e6*t_half/half:.0f} us/change incl. compile)")

# steady-state dispatch stayed sync-free and dict-free
st = ss.stats()
assert st["router_syncs"] == 0 and st["router_host_dict_ops"] == 0, st
print(f"dispatch telemetry: syncs={st['router_syncs']} "
      f"host_dict_ops={st['router_host_dict_ops']} "
      f"drain_rounds={st['router_drain_rounds']}")

# --- fault tolerance: checkpoint, 'crash', restore, continue -------------
ss.flush()                                   # drain the dispatch pipeline
checkpointer.save(ckpt_dir, half,
                  {"est": ss.state._asdict(), "ist": ss.intern._asdict()},
                  extra={"stream_cursor": half,
                         "h2label": {str(h): l
                                     for h, l in ss.host_label_map().items()}})
print(f"checkpointed sharded engine state at change {half}")

ss2 = ShardedSummarizer(cfg, n_shards=2, router_chunk=512)  # fresh process
restored = checkpointer.restore(
    ckpt_dir, half, {"est": ss2.state._asdict(), "ist": ss2.intern._asdict()})
ss2.state = type(ss2.state)(**restored["est"])
ss2.intern = type(ss2.intern)(**restored["ist"])
meta = checkpointer.load_meta(ckpt_dir, half)
ss2._h2label = {int(h): l for h, l in meta["extra"]["h2label"].items()}
cursor = meta["extra"]["stream_cursor"]

t0 = time.time()
ss.process(stream[half:])
ss2.process(stream[cursor:])
phi1, phi2 = ss.phi, ss2.phi      # sync both runs before stopping the clock
t_rest = time.time() - t0
assert phi1 == phi2, "restored run diverged!"
print(f"crash-restore verified: both runs end at phi={phi1} ✓")

print(f"[t={len(stream)}] ratio={ss.compression_ratio():.3f} "
      f"phi={ss.phi} |E|={ss.num_edges}")
print(f"stats: {ss.stats()}")
print(f"steady-state throughput: "
      f"{(len(stream)-half)/t_rest*2:.0f} changes/s on CPU "
      f"(both runs; TPU is the deployment target)")
