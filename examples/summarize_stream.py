"""End-to-end driver: sharded, device-routed summarization of a large
stream with crash-consistent checkpointing (the paper's workload,
production shape).

Feeds a fully dynamic stream through ``ShardedSummarizer`` on the default
``routing="device"`` path — the two-stage pipelined router that hashes
labels on the host (no per-change dict work), routes and interns on
device, and overlaps chunk k+1's routing with chunk k's engine rounds —
then reports the any-time compression ratio, certifies the sync-free
dispatch telemetry, and exercises the crash-consistency layer end to end:
the run is killed mid-stream at a chunk boundary, a FRESH summarizer
recovers from the checkpoint directory (last epoch checkpoint + journal
tail replay, ``recover()``), its query answers are asserted identical to
the pre-kill view, and after continuing it must land leaf-bitwise on the
uninterrupted run's state.

This example is CI-smoked (`.github/workflows/ci.yml`), so it cannot
drift from the real API.

Run:  PYTHONPATH=src python examples/summarize_stream.py [n_nodes] \
          [--proposal {minhash,magsdm}] [--objective {exact,weighted}]
"""
import argparse
import shutil
import sys
import time
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

import jax
import numpy as np

from repro.core.engine import EngineConfig, ShardedSummarizer
from repro.core.engine.state import OBJECTIVES, PROPOSALS
from repro.dist.router import DEFAULT_REPLICA_EXEC
from repro.ft.inject import SimulatedCrash, drive
from repro.graph.streams import (barabasi_albert_edges,
                                 edges_to_fully_dynamic_stream)

# policy defaults come FROM EngineConfig so this example cannot drift from
# the engine (same contract as repro/launch/stream.py)
_dflt = EngineConfig()
ap = argparse.ArgumentParser()
ap.add_argument("n_nodes", type=int, nargs="?", default=2000)
ap.add_argument("--proposal", choices=list(PROPOSALS), default=_dflt.proposal)
ap.add_argument("--objective", choices=list(OBJECTIVES),
                default=_dflt.objective)
ap.add_argument("--weight-levels", type=int, default=_dflt.weight_levels)
args = ap.parse_args()

n_nodes = args.n_nodes
edges = barabasi_albert_edges(n_nodes, 4, seed=0)
stream = edges_to_fully_dynamic_stream(edges, delete_prob=0.1, seed=1)
print(f"stream: {len(stream)} changes over {n_nodes} nodes")

# per-shard caps budget the vertex-cut replication factor, not |V|/n_shards
# (src/repro/dist/README.md)
cfg = EngineConfig(n_cap=1 << max(8, (2 * n_nodes).bit_length()),
                   m_cap=1 << max(10, (2 * len(stream)).bit_length()),
                   d_cap=64, sn_cap=48, c=24, batch=64, escape=0.2,
                   proposal=args.proposal, objective=args.objective,
                   weight_levels=args.weight_levels)
print(f"policy: proposal={cfg.proposal} objective={cfg.objective} "
      f"commit={cfg.commit}")

ckpt_dir = "/tmp/mosso_stream_ckpt"
shutil.rmtree(ckpt_dir, ignore_errors=True)


def make_engine(checkpoint_dir=None):
    return ShardedSummarizer(cfg, n_shards=2, router_chunk=512,
                             checkpoint_dir=checkpoint_dir)


ss = make_engine(ckpt_dir)
assert ss.routing == "device" and ss.sync_free and ss.pipeline
# the constructor resolves replica_exec=None to the backend-aware default
assert ss.replica_exec == DEFAULT_REPLICA_EXEC
print(f"router: chunk={ss.router_chunk} lane_cap={ss.lane_cap} "
      f"sync_free={ss.sync_free} pipeline={ss.pipeline} "
      f"replica_exec={ss.replica_exec}")

# --- crash mid-stream: every chunk is write-ahead journaled before its
# dispatch, an epoch checkpoint lands every 2 chunks, and the kill fires
# at a chunk boundary that is NOT a checkpoint (the journal tail earns it)
n_chunks = -(-len(stream) // ss.router_chunk)
kill_at = max(n_chunks // 2, 1) | 1          # odd => between checkpoints
t0 = time.time()
try:
    drive(ss, stream, ckpt_every=2, kill_at_chunk=kill_at)
    raise SystemExit("kill point never reached — stream too short?")
except SimulatedCrash as e:
    half = ss.stream_cursor
    t_half = time.time() - t0
    print(f"[t={half}] ratio={ss.compression_ratio():.3f} phi={ss.phi} "
          f"({1e6*t_half/max(half,1):.0f} us/change incl. compile)")
    print(f"crash injected: {e}")

# steady-state dispatch stayed sync-free and dict-free up to the kill
st = ss.stats()
assert st["router_syncs"] == 0 and st["router_host_dict_ops"] == 0, st
print(f"dispatch telemetry: syncs={st['router_syncs']} "
      f"host_dict_ops={st['router_host_dict_ops']} "
      f"drain_rounds={st['router_drain_rounds']}")
ss.flush()                                   # pin the view at the kill point
q_pre = ss.query()
probe = sorted({u for (u, v, _ins) in stream[:half]})[:64]
answers_pre = {u: (q_pre.degree(u), sorted(q_pre.neighbors(u)))
               for u in probe}

# --- recovery: the crashed object is ABANDONED (as a real restart would);
# a fresh engine restores the last epoch and replays the journal tail
ss2 = make_engine(ckpt_dir)
info = ss2.recover()
print(f"recovered: epoch={info['epoch']} "
      f"replayed_chunks={info['replayed_chunks']} cursor={info['cursor']}")
assert ss2.stream_cursor == half, (ss2.stream_cursor, half)

# post-recovery query answers are identical to the pre-kill view (both
# views pinned at the same flush epoch — the kill-point chunk boundary)
ss2.flush()
q_post = ss2.query()
answers_post = {u: (q_post.degree(u), sorted(q_post.neighbors(u)))
                for u in probe}
assert answers_post == answers_pre, "recovered query answers diverged!"
print(f"query answers identical across recovery ({len(probe)} labels) ✓")

# --- continue both runs to the end: the recovered run must land bitwise
# on the uninterrupted run's state (the standing recovery bar)
ref = make_engine()                          # uninterrupted reference
t0 = time.time()
ref.process(stream)
ss2.process(stream[ss2.stream_cursor:])
ref.flush(), ss2.flush()
t_rest = time.time() - t0
for a, b in zip(jax.tree.leaves(ref.state), jax.tree.leaves(ss2.state)):
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
for a, b in zip(jax.tree.leaves(ref.intern), jax.tree.leaves(ss2.intern)):
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
assert ref.phi == ss2.phi
print(f"crash-recover verified: bitwise state match, phi={ref.phi} ✓")

print(f"[t={len(stream)}] ratio={ss2.compression_ratio():.3f} "
      f"phi={ss2.phi} |E|={ss2.num_edges}")
print(f"stats: {ss2.stats()}")
print(f"steady-state throughput: "
      f"{(2 * len(stream) - half)/t_rest:.0f} changes/s on CPU "
      f"(both runs; TPU is the deployment target)")
