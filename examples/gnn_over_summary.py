"""GNN message passing directly on the lossless summary (beyond-paper).

Summarize a community graph with MoSSo, then run GraphSAGE-style mean
aggregation where the SpMM is computed from (G*, C) via summary_spmm —
|P|+|C+|+|C-| work terms instead of |E| — and verify the result matches
dense message passing exactly (losslessness means exact, not approximate).

Run:  PYTHONPATH=src python examples/gnn_over_summary.py
"""
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

import jax.numpy as jnp
import numpy as np

from repro.core.reference import MoSSo
from repro.graph.streams import edges_to_insertion_stream, sbm_edges
from repro.kernels import ops, ref

edges = sbm_edges(200, 8, 0.5, 0.01, seed=3)
algo = MoSSo(seed=1, c=40, escape=0.15)
algo.run(edges_to_insertion_stream(edges, seed=1))
out = algo.s.materialize()
ratio = algo.s.compression_ratio()
print(f"summarized: phi={algo.s.phi} vs |E|={len(edges)} (ratio {ratio:.2f})")

# pack the summary into device arrays
n = max(max(e) for e in edges) + 1
sup_ids = {sid: i for i, sid in enumerate(sorted(out.supernodes))}
n2s = np.zeros(n, np.int32)
for sid, mem in out.supernodes.items():
    for u in mem:
        n2s[u] = sup_ids[sid]
self_loop = np.zeros(len(sup_ids), bool)
p_src, p_dst = [], []
for (a, b) in out.superedges:
    if a == b:
        self_loop[sup_ids[a]] = True
    else:
        p_src += [sup_ids[a], sup_ids[b]]
        p_dst += [sup_ids[b], sup_ids[a]]


def dirpairs(pairs):
    s, d = [], []
    for (u, v) in pairs:
        s += [u, v]
        d += [v, u]
    return jnp.array(s, jnp.int32), jnp.array(d, jnp.int32)


cps, cpd = dirpairs(out.c_plus)
cms, cmd = dirpairs(out.c_minus)
es, ed = dirpairs(list(edges))

# one round of sum-aggregation, both ways
x = jnp.array(np.random.default_rng(0).normal(size=(n, 64)), jnp.float32)
y_summary = ops.summary_spmm(x, jnp.array(n2s), len(sup_ids),
                             jnp.array(p_src, jnp.int32),
                             jnp.array(p_dst, jnp.int32),
                             cps, cpd, cms, cmd, jnp.array(self_loop))
y_dense = ref.dense_spmm_ref(es, ed, x)
np.testing.assert_allclose(np.asarray(y_summary), np.asarray(y_dense),
                           rtol=1e-4, atol=1e-4)

dense_terms = 2 * len(edges)
summary_terms = (2 * len(p_src) // 2 + 2 * len(out.c_plus)
                 + 2 * len(out.c_minus) + n)
print(f"summary aggregation == dense aggregation ✓")
print(f"gather/scatter terms: dense={dense_terms}  "
      f"summary~{summary_terms}  ({summary_terms/dense_terms:.2f}x)")
print("when phi/|E| < 1, message passing over the summary moves fewer "
      "bytes — the paper's Queryable property as a compute kernel.")
