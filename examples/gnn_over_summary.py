"""GNN message passing directly on the lossless summary (beyond-paper).

Summarize a community graph with the batched engine, then run
GraphSAGE-style sum aggregation where the neighborhoods come from the
ONLINE QUERY PATH (repro.serve.query: membership -> superedge scan ->
correction patch-up) — the raw edge list is never consulted after
streaming and decode_edges() never runs.  The SpMM is computed two ways
from the compressed state:

* summary_spmm over the (G*, C) terms — |P|+|C+|+|C-| work terms, and
* a dense gather/scatter over the query-served neighborhoods,

and both must match a dense reference over the original edges exactly
(losslessness means exact, not approximate).

Run:  PYTHONPATH=src python examples/gnn_over_summary.py
"""
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

import jax.numpy as jnp
import numpy as np

from repro.core.engine import BatchedSummarizer, EngineConfig
from repro.graph.streams import edges_to_insertion_stream, sbm_edges
from repro.kernels import ops, ref

edges = sbm_edges(200, 8, 0.5, 0.01, seed=3)
n = max(max(e) for e in edges) + 1
bs = BatchedSummarizer(EngineConfig(n_cap=512, m_cap=1 << 13, d_cap=64,
                                    sn_cap=48, c=40, escape=0.15, batch=32))
bs.run(edges_to_insertion_stream(edges, seed=1))
ratio = bs.compression_ratio()
print(f"summarized: phi={bs.phi} vs |E|={len(edges)} (ratio {ratio:.2f})")

# ---- inference over the summary: neighborhoods via the query engine ----
view = bs.query()
labels = view.seen_labels()
assert len(labels) == n, "every node should carry at least one edge"
nbrs = view.neighbors_batch(labels)     # served from engine state, no decode

# pack the materialized summary into device arrays (engine-id space,
# relabeled back through bs._rev so rows line up with raw node ids)
out = bs.materialize()
eng2lab = bs._rev
sup_ids = {sid: i for i, sid in enumerate(sorted(out.supernodes))}
n2s = np.zeros(n, np.int32)
for sid, mem in out.supernodes.items():
    for u in mem:
        n2s[eng2lab[u]] = sup_ids[sid]
self_loop = np.zeros(len(sup_ids), bool)
p_src, p_dst = [], []
for (a, b) in out.superedges:
    if a == b:
        self_loop[sup_ids[a]] = True
    else:
        p_src += [sup_ids[a], sup_ids[b]]
        p_dst += [sup_ids[b], sup_ids[a]]


def dirpairs(pairs):
    s, d = [], []
    for (u, v) in pairs:
        s += [u, v]
        d += [v, u]
    return jnp.array(s, jnp.int32), jnp.array(d, jnp.int32)


cps, cpd = dirpairs([(eng2lab[a], eng2lab[b]) for (a, b) in out.c_plus])
cms, cmd = dirpairs([(eng2lab[a], eng2lab[b]) for (a, b) in out.c_minus])

# query-served gather/scatter pairs: message v -> u for v in N(u)
qs = jnp.array([v for u, s in zip(labels, nbrs) for v in sorted(s)],
               jnp.int32)
qd = jnp.array([u for u, s in zip(labels, nbrs) for _ in s], jnp.int32)
# dense reference over the RAW edge list (the only use of `edges` below)
es, ed = dirpairs(sorted(edges))

# one round of sum-aggregation, three ways
x = jnp.array(np.random.default_rng(0).normal(size=(n, 64)), jnp.float32)
y_summary = ops.summary_spmm(x, jnp.array(n2s), len(sup_ids),
                             jnp.array(p_src, jnp.int32),
                             jnp.array(p_dst, jnp.int32),
                             cps, cpd, cms, cmd, jnp.array(self_loop))
y_query = ref.dense_spmm_ref(qs, qd, x)
y_dense = ref.dense_spmm_ref(es, ed, x)
np.testing.assert_allclose(np.asarray(y_query), np.asarray(y_dense),
                           rtol=1e-4, atol=1e-4)
np.testing.assert_allclose(np.asarray(y_summary), np.asarray(y_dense),
                           rtol=1e-4, atol=1e-4)

dense_terms = 2 * len(edges)
summary_terms = (2 * len(p_src) // 2 + 2 * len(out.c_plus)
                 + 2 * len(out.c_minus) + n)
print("query-served aggregation == summary aggregation == dense ✓")
print(f"gather/scatter terms: dense={dense_terms}  "
      f"summary~{summary_terms}  ({summary_terms/dense_terms:.2f}x)")
print("when phi/|E| < 1, message passing over the summary moves fewer "
      "bytes — the paper's Queryable property served by the online "
      "query path instead of a decode.")
