"""Serve a small LM with batched requests through the KV-cache decode path.

Run:  PYTHONPATH=src python examples/serve_lm.py
"""
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

from repro.launch.serve import serve

for arch in ("minicpm3-4b", "internlm2-20b"):
    out = serve(arch, batch=4, prompt_len=8, gen_tokens=16)
    print(f"{arch}: generated {out['tokens'].shape[0]}x"
          f"{out['tokens'].shape[1]} tokens, "
          f"{out['ms_per_token']:.1f} ms/token (smoke config, CPU)")
