"""Host-routed vs device-routed ShardedSummarizer differential tests.

The device router (repro/dist/router.py) must be a drop-in replacement for
host bucketing: fed the same FD stream with the same ``process`` call
boundaries, both modes intern nodes in the same per-shard order and advance
every engine replica's PRNG identically, so the engine states — and hence
phi — are bit-comparable after every batch.  This extends the standing
differential verification bar (ROADMAP) to the routing layer.
"""
import numpy as np
import pytest

from repro.core.engine import EngineConfig, ShardedSummarizer
from repro.graph.streams import edges_to_fully_dynamic_stream, sbm_edges

from conftest import ground_truth_edges


def _cfg(**kw):
    base = dict(n_cap=160, m_cap=1024, d_cap=48, sn_cap=32, c=8, batch=8,
                escape=0.3)
    base.update(kw)
    return EngineConfig(**base)


def _stream(seed=11):
    edges = sbm_edges(44, 4, 0.5, 0.05, seed=seed)
    return edges_to_fully_dynamic_stream(edges, delete_prob=0.2,
                                         seed=seed + 1)


@pytest.mark.parametrize("n_shards", [1, 2])
def test_device_vs_host_routing_differential(n_shards):
    """Identical phi + lossless decode after every batch, 1 device."""
    stream = _stream()
    cfg = _cfg()
    kw = dict(n_shards=n_shards, router_chunk=64)
    dev = ShardedSummarizer(cfg, routing="device", **kw)
    host = ShardedSummarizer(cfg, routing="host", **kw)
    live = set()

    for off in range(0, len(stream), 64):
        chunk = stream[off:off + 64]
        dev.process(chunk)
        host.process(chunk)
        for (u, v, ins) in chunk:
            e = (min(u, v), max(u, v))
            live.add(e) if ins else live.discard(e)
        tag = f"off={off}"
        # no lane overflow at this scale: pure device routing throughout
        assert dev.router_overflows == 0, tag
        # identical per-shard phi — the engines are in lockstep
        assert dev.shard_phis() == host.shard_phis(), tag
        # both satisfy the phi invariant and decode losslessly
        dm, hm = dev.materialize().validate(), host.materialize().validate()
        assert dm.phi == dev.phi == dev.phi_recomputed(), tag
        assert hm.phi == host.phi == host.phi_recomputed(), tag
        assert dm.decode_edges() == live, tag
        assert hm.decode_edges() == live, tag

    assert live == ground_truth_edges(stream)
    assert 0 < dev.phi <= len(live)
    assert dev.stats()["routing"] == "device"
    assert host.stats()["routing"] == "host"


def test_device_routing_states_bit_identical_to_host():
    """Beyond phi: every engine-state leaf matches between the modes."""
    stream = _stream(seed=21)
    cfg = _cfg()
    dev = ShardedSummarizer(cfg, routing="device", n_shards=2,
                            router_chunk=128).run(stream)
    host = ShardedSummarizer(cfg, routing="host", n_shards=2,
                             router_chunk=128).run(stream)
    assert dev.router_overflows == 0
    for d, h in zip(dev.host_states(), host.host_states()):
        for name, dl, hl in zip(d._fields, d, h):
            np.testing.assert_array_equal(
                np.asarray(dl), np.asarray(hl), err_msg=name)
    for d, h in zip(dev.host_interns(), host.host_interns()):
        assert int(d.n_nodes) == int(h.n_nodes)
        np.testing.assert_array_equal(np.asarray(d.l2g), np.asarray(h.l2g))


def test_lane_overflow_falls_back_to_host_path_losslessly():
    """A tiny lane_cap forces overflow: the spilled suffix replays through
    the host path in stream order, so the run stays lossless and the
    overflow is counted and surfaced."""
    stream = _stream(seed=31)
    ss = ShardedSummarizer(_cfg(), routing="device", n_shards=2,
                           router_chunk=64, lane_cap=1)
    ss.run(stream)
    assert ss.router_overflows > 0
    assert ss.stats()["router_overflows"] == ss.router_overflows
    truth = ground_truth_edges(stream)
    assert ss.live_edges() == truth
    out = ss.materialize()
    assert out.decode_edges() == truth
    assert out.phi == ss.phi == ss.phi_recomputed()


@pytest.mark.parametrize("routing", ["device", "host"])
def test_node_capacity_drop_raises_at_sync(routing):
    """Exceeding per-shard n_cap cannot silently lose changes: the device
    intern counter trips a RuntimeError at the next host sync point."""
    stream = _stream(seed=41)
    ss = ShardedSummarizer(_cfg(n_cap=16), routing=routing, n_shards=2,
                           router_chunk=64)
    ss.run(stream)    # streaming itself must NOT raise (raise-at-sync)
    with pytest.raises(RuntimeError, match="node capacity exceeded"):
        ss.stats()


def test_shard_of_is_read_only():
    """Querying placement must not assign gids (it would desynchronize a
    differential pair of runs): unseen labels raise instead."""
    stream = _stream(seed=61)
    ss = ShardedSummarizer(_cfg(), routing="device", n_shards=2,
                           router_chunk=64).run(stream)
    u, v, _ = stream[0]
    assert ss.shard_of(u, v) == min(ss._gids[u], ss._gids[v]) % 2
    n_before = len(ss._gids)
    with pytest.raises(LookupError, match="has not been streamed"):
        ss.shard_of("never-streamed-a", "never-streamed-b")
    assert len(ss._gids) == n_before


def test_arbitrary_hashable_labels_roundtrip():
    """Caller labels never touch the device: strings stream and decode."""
    stream = [(f"n{u}", f"n{v}", ins) for (u, v, ins) in _stream(seed=51)]
    ss = ShardedSummarizer(_cfg(), routing="device", n_shards=2,
                           router_chunk=64).run(stream)
    truth = ground_truth_edges(stream)
    assert ss.live_edges() == truth
    assert ss.materialize().decode_edges() == truth
