"""Host-routed vs device-routed ShardedSummarizer differential tests.

The device router (repro/dist/router.py) must be a drop-in replacement for
host bucketing: fed the same FD stream with the same ``process`` call
boundaries, both modes intern nodes in the same per-shard order and advance
every engine replica's PRNG identically, so the engine states — and hence
phi — are bit-comparable after every batch.  This extends the standing
differential verification bar (ROADMAP) to the routing layer.
"""
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:      # container has no hypothesis; deterministic shim
    from repro.testing.proptest import given, settings, strategies as st

from repro.core.engine import EngineConfig, ShardedSummarizer
from repro.graph.streams import edges_to_fully_dynamic_stream, sbm_edges

from conftest import ground_truth_edges


def _cfg(**kw):
    base = dict(n_cap=160, m_cap=1024, d_cap=48, sn_cap=32, c=8, batch=8,
                escape=0.3)
    base.update(kw)
    return EngineConfig(**base)


def _stream(seed=11):
    edges = sbm_edges(44, 4, 0.5, 0.05, seed=seed)
    return edges_to_fully_dynamic_stream(edges, delete_prob=0.2,
                                         seed=seed + 1)


@pytest.mark.parametrize("n_shards", [1, 2])
def test_device_vs_host_routing_differential(n_shards):
    """Identical phi + lossless decode after every batch, 1 device."""
    stream = _stream()
    cfg = _cfg()
    kw = dict(n_shards=n_shards, router_chunk=64)
    dev = ShardedSummarizer(cfg, routing="device", **kw)
    host = ShardedSummarizer(cfg, routing="host", **kw)
    live = set()

    for off in range(0, len(stream), 64):
        chunk = stream[off:off + 64]
        dev.process(chunk)
        host.process(chunk)
        for (u, v, ins) in chunk:
            e = (min(u, v), max(u, v))
            live.add(e) if ins else live.discard(e)
        tag = f"off={off}"
        # no lane overflow at this scale: pure device routing throughout
        assert dev.router_overflows == 0, tag
        # identical per-shard phi — the engines are in lockstep
        assert dev.shard_phis() == host.shard_phis(), tag
        # both satisfy the phi invariant and decode losslessly
        dm, hm = dev.materialize().validate(), host.materialize().validate()
        assert dm.phi == dev.phi == dev.phi_recomputed(), tag
        assert hm.phi == host.phi == host.phi_recomputed(), tag
        assert dm.decode_edges() == live, tag
        assert hm.decode_edges() == live, tag

    assert live == ground_truth_edges(stream)
    assert 0 < dev.phi <= len(live)
    assert dev.stats()["routing"] == "device"
    assert host.stats()["routing"] == "host"


def test_device_routing_states_bit_identical_to_host():
    """Beyond phi: every engine-state leaf matches between the modes."""
    stream = _stream(seed=21)
    cfg = _cfg()
    dev = ShardedSummarizer(cfg, routing="device", n_shards=2,
                            router_chunk=128).run(stream)
    host = ShardedSummarizer(cfg, routing="host", n_shards=2,
                             router_chunk=128).run(stream)
    assert dev.router_overflows == 0
    for d, h in zip(dev.host_states(), host.host_states()):
        for name, dl, hl in zip(d._fields, d, h):
            np.testing.assert_array_equal(
                np.asarray(dl), np.asarray(hl), err_msg=name)
    for d, h in zip(dev.host_interns(), host.host_interns()):
        assert int(d.n_nodes) == int(h.n_nodes)
        np.testing.assert_array_equal(np.asarray(d.l2h), np.asarray(h.l2h))


def test_lane_overflow_drains_on_device_by_default():
    """A tiny lane_cap no longer spills to the host: the default drain
    budget guarantees delivery, so the router re-ranks the suffix and runs
    extra all_to_all rounds instead — lossless, sync-free, no fallback."""
    stream = _stream(seed=31)
    ss = ShardedSummarizer(_cfg(), routing="device", n_shards=2,
                           router_chunk=64, lane_cap=1)
    assert ss.sync_free and ss.router_geometry.drain_guaranteed
    ss.run(stream)
    st = ss.stats()
    assert ss.router_overflows == 0 and st["router_syncs"] == 0
    assert st["router_drain_rounds"] > 0       # the drain loop actually ran
    truth = ground_truth_edges(stream)
    assert ss.live_edges() == truth
    out = ss.materialize()
    assert out.decode_edges() == truth
    assert out.phi == ss.phi == ss.phi_recomputed()


def test_bounded_drain_budget_falls_back_to_host_path_losslessly():
    """An explicitly lowered max_drain_rounds keeps the PR-2 contract: the
    undelivered suffix replays through the host path in stream order, the
    spill is counted, and the run stays lossless."""
    stream = _stream(seed=31)
    ss = ShardedSummarizer(_cfg(), routing="device", n_shards=2,
                           router_chunk=64, lane_cap=1, max_drain_rounds=1)
    assert not ss.sync_free          # bounded budget -> per-chunk watermark
    ss.run(stream)
    assert ss.router_overflows > 0
    assert ss.stats()["router_overflows"] == ss.router_overflows
    assert ss.stats()["router_syncs"] > 0
    truth = ground_truth_edges(stream)
    assert ss.live_edges() == truth
    out = ss.materialize()
    assert out.decode_edges() == truth
    assert out.phi == ss.phi == ss.phi_recomputed()


@pytest.mark.parametrize("routing", ["device", "host"])
def test_node_capacity_drop_raises_at_sync(routing):
    """Exceeding per-shard n_cap cannot silently lose changes: the device
    intern counter trips a RuntimeError at the next host sync point."""
    stream = _stream(seed=41)
    ss = ShardedSummarizer(_cfg(n_cap=16), routing=routing, n_shards=2,
                           router_chunk=64)
    ss.run(stream)    # streaming itself must NOT raise (raise-at-sync)
    with pytest.raises(RuntimeError, match="node capacity exceeded"):
        ss.stats()


def test_shard_of_is_read_only():
    """Placement is a pure function of the 62-bit label hash — host
    bucketing, the device router, and ``shard_of`` must all agree — and
    querying it mutates nothing: unseen labels raise instead of being
    assigned."""
    from repro.dist.labelhash import hash_label

    stream = _stream(seed=61)
    ss = ShardedSummarizer(_cfg(), routing="device", n_shards=2,
                           router_chunk=64).run(stream)
    u, v, _ = stream[0]
    assert ss.shard_of(u, v) == min(hash_label(u), hash_label(v)) % 2
    n_before = len(ss._h2label)
    with pytest.raises(LookupError, match="has not been streamed"):
        ss.shard_of("never-streamed-a", "never-streamed-b")
    assert len(ss._h2label) == n_before


def test_arbitrary_hashable_labels_roundtrip():
    """Caller labels never touch the device: strings stream and decode."""
    stream = [(f"n{u}", f"n{v}", ins) for (u, v, ins) in _stream(seed=51)]
    ss = ShardedSummarizer(_cfg(), routing="device", n_shards=2,
                           router_chunk=64).run(stream)
    truth = ground_truth_edges(stream)
    assert ss.live_edges() == truth
    assert ss.materialize().decode_edges() == truth


# --------------------------------------------------------------------------- #
# device-resident overflow drain + elided watermark sync (PR 3)
# --------------------------------------------------------------------------- #


def _skew_hub(leaves):
    """A hub label whose 62-bit hash undercuts every leaf's, so the
    canonical pair key ``min(h(u), h(v))`` is always the hub's and every
    change routes to ONE shard — the worst case for the capacity-bounded
    lanes.  (Placement is hash-based since PR 4; being streamed first no
    longer matters.)"""
    from repro.dist.labelhash import hash_label
    lo = min(hash_label(x) for x in leaves)
    return next(h for h in (f"hub{j}" for j in range(100_000))
                if hash_label(h) < lo)


def _skew_stream(n_leaves, delete_every=3):
    """Adversarial key skew: a star around a minimal-hash hub."""
    leaves = [f"x{i:03d}" for i in range(n_leaves)]
    hub = _skew_hub(leaves)
    ins = [(hub, x, True) for x in leaves]
    dels = [(hub, x, False) for x in leaves[::delete_every]]
    return ins + dels


def test_key_skew_multi_round_drain_bit_identical_to_host():
    """All changes hash to one shard at a tiny lane_cap: the drain loop
    delivers each chunk over many all_to_all rounds, losslessly and
    order-preservingly — the final engine/intern states are bit-identical
    to host routing, which is the strongest order statement available."""
    stream = _skew_stream(60)
    cfg = _cfg()
    dev = ShardedSummarizer(cfg, routing="device", n_shards=2,
                            router_chunk=64, lane_cap=2)
    host = ShardedSummarizer(cfg, routing="host", n_shards=2,
                             router_chunk=64)
    for off in range(0, len(stream), 64):
        dev.process(stream[off:off + 64])
        host.process(stream[off:off + 64])
    st = dev.stats()
    assert dev.router_overflows == 0       # no host replay was needed
    assert st["router_syncs"] == 0         # and no per-chunk watermark fetch
    assert st["router_drain_rounds"] >= 2  # genuinely multi-round
    assert dev.shard_phis() == host.shard_phis()
    for d, h in zip(dev.host_states(), host.host_states()):
        for name, dl, hl in zip(d._fields, d, h):
            np.testing.assert_array_equal(
                np.asarray(dl), np.asarray(hl), err_msg=name)
    for d, h in zip(dev.host_interns(), host.host_interns()):
        assert int(d.n_nodes) == int(h.n_nodes)
        np.testing.assert_array_equal(np.asarray(d.l2h), np.asarray(h.l2h))
    truth = ground_truth_edges(stream)
    assert dev.live_edges() == truth
    assert dev.materialize().decode_edges() == truth


@settings(max_examples=8, deadline=None)
@given(st.integers(20, 70), st.integers(1, 4), st.integers(2, 5))
def test_key_skew_drain_property(n_leaves, lane_cap, delete_every):
    """Property: for any star size / lane capacity / deletion cadence, the
    drain loop delivers fully on device (no fallback, no syncs) and the
    result is lossless and phi-identical to host routing."""
    stream = _skew_stream(n_leaves, delete_every)
    cfg = _cfg()
    dev = ShardedSummarizer(cfg, routing="device", n_shards=2,
                            router_chunk=32, lane_cap=lane_cap)
    host = ShardedSummarizer(cfg, routing="host", n_shards=2,
                             router_chunk=32)
    for off in range(0, len(stream), 32):
        dev.process(stream[off:off + 32])
        host.process(stream[off:off + 32])
    assert dev.router_overflows == 0 and dev.router_syncs == 0
    assert dev.shard_phis() == host.shard_phis()
    truth = ground_truth_edges(stream)
    assert dev.live_edges() == truth
    assert dev.materialize().decode_edges() == truth


def test_no_overflow_geometry_elides_watermark_sync():
    """With lane_cap == chunk // n_dev overflow is statically impossible:
    the compiled program carries no watermark collective, the geometry
    proves it (static_no_overflow), and process() performs zero per-chunk
    host syncs (router_syncs counts every watermark fetch)."""
    stream = _stream(seed=71)
    ss = ShardedSummarizer(_cfg(), routing="device", n_shards=2,
                           router_chunk=64, lane_cap=64)
    g = ss.router_geometry
    assert g.static_no_overflow and g.max_drain_rounds == 1
    assert ss.sync_free
    for off in range(0, len(stream), 64):
        ss.process(stream[off:off + 64])
    st = ss.stats()
    assert st["router_syncs"] == 0 and st["router_sync_free"]
    assert st["router_drain_rounds"] == 0 and ss.router_overflows == 0
    assert ss.live_edges() == ground_truth_edges(stream)


def test_chunk_sync_forces_watermark_fetch_with_identical_results():
    """chunk_sync=True reinstates the per-chunk fetch (the measurement
    baseline for the sync-elision benchmark) without changing any result:
    same engine states, same phi, one sync per chunk."""
    stream = _stream(seed=81)
    free = ShardedSummarizer(_cfg(), routing="device", n_shards=2,
                             router_chunk=64)
    sync = ShardedSummarizer(_cfg(), routing="device", n_shards=2,
                             router_chunk=64, chunk_sync=True)
    assert free.sync_free and not sync.sync_free
    n_chunks = 0
    for off in range(0, len(stream), 64):
        free.process(stream[off:off + 64])
        sync.process(stream[off:off + 64])
        n_chunks += 1
    assert free.router_syncs == 0
    assert sync.router_syncs == n_chunks
    assert free.shard_phis() == sync.shard_phis()
    for a, b in zip(free.host_states(), sync.host_states()):
        for name, al, bl in zip(a._fields, a, b):
            np.testing.assert_array_equal(
                np.asarray(al), np.asarray(bl), err_msg=name)


def test_skew_drain_bit_identical_at_two_shards_per_device():
    """The skew-drain differential scaled to the mesh this process sees:
    n_shards = 2 * n_devices, so under the CI router-stress job
    (``XLA_FLAGS=--xla_force_host_platform_device_count=8``) the drain
    loop's all_to_all, pmin watermark, and multi-round append all run on a
    REAL 8-device mesh inside this file — on the default 1-device tier-1
    run it degrades to the cheap 2-shard case."""
    import jax
    n_shards = 2 * len(jax.devices())
    stream = _skew_stream(60)
    cfg = _cfg()
    dev = ShardedSummarizer(cfg, routing="device", n_shards=n_shards,
                            router_chunk=64, lane_cap=2)
    host = ShardedSummarizer(cfg, routing="host", n_shards=n_shards,
                             router_chunk=64)
    assert dev.router_geometry.n_dev == len(jax.devices())
    assert dev.sync_free
    for off in range(0, len(stream), 64):
        dev.process(stream[off:off + 64])
        host.process(stream[off:off + 64])
    st = dev.stats()
    assert dev.router_overflows == 0 and st["router_syncs"] == 0
    assert st["router_drain_rounds"] >= 2
    assert dev.shard_phis() == host.shard_phis()
    for d, h in zip(dev.host_states(), host.host_states()):
        for name, dl, hl in zip(d._fields, d, h):
            np.testing.assert_array_equal(
                np.asarray(dl), np.asarray(hl), err_msg=name)
    truth = ground_truth_edges(stream)
    assert dev.live_edges() == truth
    assert dev.materialize().decode_edges() == truth


def test_default_lane_cap_is_sync_free_by_construction():
    """The out-of-the-box configuration must never pay the per-chunk sync:
    the default lane_cap + drain budget always yields a delivery
    guarantee."""
    ss = ShardedSummarizer(_cfg(), routing="device", n_shards=2,
                           router_chunk=128)
    assert ss.router_geometry.drain_guaranteed and ss.sync_free


# --------------------------------------------------------------------------- #
# hash-interned labels + pipelined two-stage dispatch (PR 4)
# --------------------------------------------------------------------------- #


def test_pipelined_vs_serial_dispatch_bit_identical_under_key_skew():
    """The two-stage pipeline (chunk k+1 routed while chunk k steps) is a
    pure dispatch-order change: under forced key skew with multi-round
    drains, pipelined and serial device dispatch produce bitwise-identical
    engine/intern states and identical router telemetry — and the pipelined
    run's dispatch performed zero host fetches and zero host dict ops."""
    stream = _skew_stream(60)
    cfg = _cfg()
    pipe = ShardedSummarizer(cfg, routing="device", n_shards=2,
                             router_chunk=64, lane_cap=2)
    ser = ShardedSummarizer(cfg, routing="device", n_shards=2,
                            router_chunk=64, lane_cap=2, pipeline=False)
    assert pipe.pipeline and not ser.pipeline
    for off in range(0, len(stream), 64):
        pipe.process(stream[off:off + 64])
        ser.process(stream[off:off + 64])
    sp, ss_ = pipe.stats(), ser.stats()
    assert sp["router_drain_rounds"] >= 2      # genuinely multi-round
    assert sp["router_syncs"] == 0 and sp["router_host_dict_ops"] == 0
    tele = [k for k in sp if k.startswith("router_")
            and k != "router_pipelined"]
    assert {k: sp[k] for k in tele} == {k: ss_[k] for k in tele}
    assert sp["router_pipelined"] and not ss_["router_pipelined"]
    for a, b in zip(pipe.host_states(), ser.host_states()):
        for name, al, bl in zip(a._fields, a, b):
            np.testing.assert_array_equal(
                np.asarray(al), np.asarray(bl), err_msg=name)
    for a, b in zip(pipe.host_interns(), ser.host_interns()):
        assert int(a.n_nodes) == int(b.n_nodes)
        np.testing.assert_array_equal(np.asarray(a.l2h), np.asarray(b.l2h))
    truth = ground_truth_edges(stream)
    assert pipe.live_edges() == truth
    assert pipe.materialize().decode_edges() == truth


def test_steady_state_dispatch_is_fetch_free_and_dict_free():
    """The acceptance contract of the pipelined path: a default-geometry
    device-routed run performs zero per-chunk device-to-host fetches
    (``router_syncs``) and zero per-chunk host dict operations
    (``router_host_dict_ops``) — interleaved sync points (``phi``) must
    not void either counter."""
    stream = _stream(seed=91)
    ss = ShardedSummarizer(_cfg(), routing="device", n_shards=2,
                           router_chunk=64)
    assert ss.sync_free and ss.pipeline
    for off in range(0, len(stream), 64):
        ss.process(stream[off:off + 64])
        _ = ss.phi                      # sync point between chunks
    st = ss.stats()
    assert st["router_syncs"] == 0
    assert st["router_host_dict_ops"] == 0
    assert st["router_sync_free"] and st["router_pipelined"]
    assert ss.live_edges() == ground_truth_edges(stream)


def test_label_hash_collision_raises_loudly():
    """Two distinct labels landing on one 62-bit hash must never silently
    merge: the lazy reverse-map fold detects the collision and raises."""
    from repro.dist import labelhash

    ss = ShardedSummarizer(_cfg(), routing="device", n_shards=2,
                           router_chunk=64)
    h = labelhash.hash_label("a")
    ss.process([("a", "b", True)])
    # forge a buffered chunk claiming label "evil-twin" has a's hash
    hi = np.array([(h >> 31)], np.int32)
    lo = np.array([h & labelhash.MASK31], np.int32)
    ss._label_buf.append((["evil-twin"], hi, lo))
    with pytest.raises(RuntimeError, match="hash collision"):
        ss.stats()


def test_pipelined_skew_drain_8_fake_devices_subprocess():
    """Satellite 8-device variant: the pipelined two-stage dispatch with
    multi-round drains on a REAL 8-device mesh (subprocess, fake host
    devices) stays bitwise-identical to serial dispatch and to host
    bucketing, with zero syncs and zero host dict ops."""
    import os
    import subprocess
    import sys
    import textwrap
    from pathlib import Path

    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = str(Path(__file__).resolve().parent.parent / "src")
    code = textwrap.dedent("""
        import jax, numpy as np
        from repro.core.engine import EngineConfig, ShardedSummarizer
        from repro.dist.labelhash import hash_label

        assert len(jax.devices()) == 8
        cfg = EngineConfig(n_cap=128, m_cap=1024, d_cap=32, sn_cap=24,
                           c=8, batch=8, escape=0.3)
        leaves = ["x%03d" % i for i in range(90)]
        lo = min(hash_label(x) for x in leaves)
        hub = next(h for h in ("hub%d" % j for j in range(100000))
                   if hash_label(h) < lo)
        stream = [(hub, x, True) for x in leaves]
        kw = dict(n_shards=16, router_chunk=128, lane_cap=2)
        pipe = ShardedSummarizer(cfg, routing="device", **kw)
        ser = ShardedSummarizer(cfg, routing="device", pipeline=False, **kw)
        host = ShardedSummarizer(cfg, routing="host", n_shards=16,
                                 router_chunk=128)
        assert pipe.router_geometry.n_dev == 8
        assert pipe.sync_free and pipe.pipeline and not ser.pipeline
        for off in range(0, len(stream), 128):
            pipe.process(stream[off:off + 128])
            ser.process(stream[off:off + 128])
            host.process(stream[off:off + 128])
        st = pipe.stats()
        assert st["router_syncs"] == 0 and st["router_host_dict_ops"] == 0
        assert st["router_drain_rounds"] >= 2, st
        for other in (ser, host):
            assert pipe.shard_phis() == other.shard_phis()
            for a, b in zip(pipe.host_states(), other.host_states()):
                for name, al, bl in zip(a._fields, a, b):
                    np.testing.assert_array_equal(
                        np.asarray(al), np.asarray(bl), err_msg=name)
        truth = {(min(hub, x), max(hub, x)) for x in leaves}
        assert pipe.live_edges() == truth
        assert pipe.materialize().decode_edges() == truth
        print("8-device pipelined skew drain OK:",
              st["router_drain_rounds"], "rounds")
    """)
    out = subprocess.run([sys.executable, "-c", code],
                         capture_output=True, text=True, env=env,
                         timeout=900)
    assert out.returncode == 0, out.stderr[-3000:]
    assert "OK" in out.stdout


# --------------------------------------------------------------------------- #
# vmapped shard replicas (predicated trial engine, PR 5)
# --------------------------------------------------------------------------- #


def test_replica_exec_vmap_vs_map_vs_host_bitwise_on_key_skew():
    """replica_exec is a pure lowering change: under forced key skew with
    multi-round drains, the vmapped replica layout, the lax.map layout,
    and host routing (through the vmapped bucketed step) produce
    leaf-bitwise identical engine AND intern states — the strongest
    statement that batching replicas changes no PRNG draw, no intern
    order, and no trial outcome."""
    stream = _skew_stream(60)
    cfg = _cfg()
    kw = dict(n_shards=2, router_chunk=64)
    vm = ShardedSummarizer(cfg, routing="device", lane_cap=2,
                           replica_exec="vmap", **kw)
    mp = ShardedSummarizer(cfg, routing="device", lane_cap=2,
                           replica_exec="map", **kw)
    host = ShardedSummarizer(cfg, routing="host", replica_exec="vmap", **kw)
    assert vm.replica_exec == "vmap" and mp.replica_exec == "map"
    for off in range(0, len(stream), 64):
        vm.process(stream[off:off + 64])
        mp.process(stream[off:off + 64])
        host.process(stream[off:off + 64])
    assert vm.stats()["router_drain_rounds"] >= 2   # genuinely multi-round
    for other in (mp, host):
        assert vm.shard_phis() == other.shard_phis()
        for a, b in zip(vm.host_states(), other.host_states()):
            for name, al, bl in zip(a._fields, a, b):
                np.testing.assert_array_equal(
                    np.asarray(al), np.asarray(bl), err_msg=name)
        for a, b in zip(vm.host_interns(), other.host_interns()):
            assert int(a.n_nodes) == int(b.n_nodes)
            np.testing.assert_array_equal(np.asarray(a.l2h),
                                          np.asarray(b.l2h))
    truth = ground_truth_edges(stream)
    assert vm.live_edges() == truth
    assert vm.materialize().decode_edges() == truth


def test_replica_exec_default_is_backend_aware_and_validated():
    """The resolved default must be a legal mode (vmap on accelerators,
    map on the XLA CPU backend — see repro/dist/router.py), and an unknown
    mode must fail fast."""
    import jax

    from repro.dist.router import DEFAULT_REPLICA_EXEC, REPLICA_EXEC_MODES

    assert DEFAULT_REPLICA_EXEC in REPLICA_EXEC_MODES
    if jax.default_backend() == "cpu" and "REPRO_REPLICA_EXEC" not in \
            __import__("os").environ:
        assert DEFAULT_REPLICA_EXEC == "map"
    ss = ShardedSummarizer(_cfg(), n_shards=2, router_chunk=64)
    assert ss.replica_exec == DEFAULT_REPLICA_EXEC
    with pytest.raises(ValueError, match="replica_exec"):
        ShardedSummarizer(_cfg(), n_shards=2, replica_exec="pmap")


def test_label_buffer_compacts_on_long_zero_sync_runs():
    """A dispatch-only run must not buffer every label occurrence until
    the next sync: the buffer compacts to unique hashes every 64 chunks
    (numpy only — the dict-op and sync counters stay 0), and decoding
    after the eventual sync is unaffected."""
    edges = sbm_edges(120, 4, 0.4, 0.02, seed=101)
    stream = edges_to_fully_dynamic_stream(edges, delete_prob=0.2, seed=102)
    assert len(stream) > 64 * 8             # many chunks, one process call
    ss = ShardedSummarizer(_cfg(n_cap=512, m_cap=4096), routing="device",
                           n_shards=2, router_chunk=8)
    ss.process(stream)
    # > 64 chunks ran; without compaction there would be 2 entries/chunk
    assert len(ss._label_buf) < 2 * len(stream) // 8, len(ss._label_buf)
    st = ss.stats()
    assert st["router_syncs"] == 0 and st["router_host_dict_ops"] == 0
    assert ss.live_edges() == ground_truth_edges(stream)
    assert ss.materialize().decode_edges() == ground_truth_edges(stream)
