"""Contracts of the stable 62-bit label hash (repro/dist/labelhash.py).

The hash defines shard placement and intern-table keys, so two things are
load-bearing forever: the scalar (`hash_label`) and vectorized
(`hash_words`) paths must agree for every label a chunk can carry, and
labels that were one dict key under the old gid scheme (numeric equality)
must stay one node.
"""
import numpy as np

from repro.dist.labelhash import (MASK31, combine, hash_label, hash_words)


def _words_via_scalar(labels):
    comb = [hash_label(x) for x in labels]
    return ([c >> 31 for c in comb], [c & MASK31 for c in comb])


def test_scalar_and_vectorized_paths_agree():
    """Every dtype route numpy can pick for a chunk (int64, uint64,
    object, str, float) must reproduce hash_label element for element —
    including ints in [2**63, 2**64), which vectorize through a uint64
    array but take the scalar fast path one at a time."""
    cases = [
        [0, 1, -1, 5, 2**31, 2**62 - 1, -(2**63), 2**63 - 1],   # int64
        [2**63, 2**63 + 5, 2**64 - 1],                          # uint64
        [2**64 + 3, -(2**63) - 1, "mixed", 7],                  # object
        ["a", "b", "", "n001"],                                 # str
        [b"x", b""],                                            # bytes
        [1.5, -0.25, 2.0, 1e300],                               # float
        [(1, 2), (3, 4)],                                       # tuples
    ]
    for labels in cases:
        hi, lo = hash_words(labels)
        shi, slo = _words_via_scalar(labels)
        np.testing.assert_array_equal(hi, np.asarray(shi, np.int64),
                                      err_msg=repr(labels))
        np.testing.assert_array_equal(lo, np.asarray(slo, np.int64),
                                      err_msg=repr(labels))
        # device words are 31-bit non-negative int32
        assert hi.dtype == np.int32 and lo.dtype == np.int32
        assert (hi >= 0).all() and (lo >= 0).all()
        # combine() round-trips to the scalar form
        np.testing.assert_array_equal(
            combine(hi, lo), np.asarray([hash_label(x) for x in labels]))


def test_numeric_label_equality_is_preserved():
    """Labels that were one dict key under the gid scheme stay one node:
    bools and integral floats canonicalize to int before hashing."""
    assert hash_label(True) == hash_label(1) == hash_label(1.0)
    assert hash_label(False) == hash_label(0) == hash_label(0.0)
    assert hash_label(np.int32(7)) == hash_label(7) == hash_label(7.0)
    assert hash_label(np.float32(2.0)) == hash_label(2)
    assert hash_label(float(2**53)) == hash_label(2**53)
    # non-integral floats are their own nodes, stable across widths
    assert hash_label(1.5) == hash_label(np.float64(1.5))
    assert hash_label(1.5) != hash_label(1)


def test_distinct_labels_get_distinct_hashes_at_test_scale():
    """No 62-bit collisions across a realistic mixed label population
    (a collision here would be a broken hash, not bad luck)."""
    labels = (list(range(-500, 500))
              + [f"n{i}" for i in range(1000)]
              + [(i, i + 1) for i in range(200)]
              + [i + 0.5 for i in range(200)])
    combs = [hash_label(x) for x in labels]
    assert len(set(combs)) == len(combs)
    assert all(0 <= c < (1 << 62) for c in combs)


def test_type_tags_separate_str_bytes_int_float():
    """'5', b'5', and 5 are distinct dict keys, hence distinct nodes —
    and a non-integral float must not collide with its repr string."""
    assert len({hash_label("5"), hash_label(b"5"), hash_label(5)}) == 3
    assert hash_label(1.5) != hash_label("1.5")
    assert hash_label(1e300) != hash_label("1e+300")
