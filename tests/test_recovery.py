"""Crash-consistent recovery: the bitwise replay bar.

The contract (src/repro/checkpoint/summary.py): a summarizer killed at ANY
chunk boundary and recovered from its checkpoint directory — latest valid
epoch + deterministic journal-tail replay — must be leaf-bitwise equal to
the uninterrupted run, both at the kill point and after continuing to the
end of the stream.  Faults are injected with :mod:`repro.ft.inject`; every
scenario recovers through the same public ``recover()`` path a production
driver uses (``launch/stream.py --resume``), never through engine
internals.

Execution-variant coverage (replica_exec x trial_backend x policy) comes
from the CI router-stress matrix running this file under the REPRO_* env
vars; the tests only use defaults.
"""
import os
import subprocess
import sys
import textwrap
from pathlib import Path

import jax
import numpy as np
import pytest

from repro.checkpoint import checkpointer
from repro.checkpoint.summary import ConfigMismatchError
from repro.core.engine import (BatchedSummarizer, EngineConfig,
                               ShardedSummarizer)
from repro.ft import inject
from repro.graph.streams import edges_to_fully_dynamic_stream, sbm_edges

SRC = str(Path(__file__).resolve().parent.parent / "src")

CFG = EngineConfig(n_cap=160, m_cap=1024, d_cap=48, sn_cap=32, c=8,
                   batch=8, escape=0.3)


def _stream(n=56):
    edges = sbm_edges(44, 4, 0.5, 0.05, seed=11)
    return edges_to_fully_dynamic_stream(edges, delete_prob=0.2, seed=11)[:n]


def _labels(stream, k=10):
    """First k distinct caller labels, in stream order (all seen, so the
    query layer cannot LookupError)."""
    seen = []
    for (u, v, _ins) in stream:
        for lab in (u, v):
            if lab not in seen:
                seen.append(lab)
    return seen[:k]


def assert_leaves_equal(a, b):
    la, lb = jax.tree.leaves(a), jax.tree.leaves(b)
    assert len(la) == len(lb)
    for x, y in zip(la, lb):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def _batched(ckpt_dir=None):
    return BatchedSummarizer(CFG, checkpoint_dir=ckpt_dir)


def _sharded(ckpt_dir=None, **kw):
    kw.setdefault("n_shards", 2)
    kw.setdefault("router_chunk", 32)
    return ShardedSummarizer(CFG, checkpoint_dir=ckpt_dir, **kw)


def _snapshots(summ, stream):
    """Uninterrupted run, recording the closure after every chunk."""
    size = summ.dispatch_chunk
    snaps = []
    for off in range(0, len(stream), size):
        summ.process(stream[off:off + size])
        summ.flush()
        snaps.append((summ._ckpt_tree(), summ._ckpt_host()))
    return snaps


# --------------------------------------------------------------------------- #
# the bar: kill at EVERY chunk boundary, recover, bitwise-match
# --------------------------------------------------------------------------- #


def test_batched_kill_at_every_chunk_boundary_bitwise(tmp_path):
    stream = _stream(56)
    ref = _batched()
    snaps = _snapshots(ref, stream)         # 7 chunks of batch=8
    n_chunks = len(snaps)
    assert n_chunks == 7
    for k in range(n_chunks + 1):           # incl. kill after final dispatch
        d = str(tmp_path / f"k{k}")
        crashed = _batched(d)
        with pytest.raises(inject.SimulatedCrash):
            inject.drive(crashed, stream, ckpt_every=2, kill_at_chunk=k)
        rec = _batched(d)
        info = rec.recover()
        # recovery lands exactly at the kill point: k chunks were journaled
        # and dispatched before the crash, none after
        assert rec.stream_cursor == k * CFG.batch, info
        if k > 0:
            assert_leaves_equal(rec._ckpt_tree(), snaps[k - 1][0])
            assert rec._ckpt_host() == snaps[k - 1][1]
        inject.drive(rec, stream, start=rec.stream_cursor)
        assert_leaves_equal(rec.state, ref.state)
        assert rec._ids == ref._ids and rec._rev == ref._rev
        s1, s2 = ref.stats(), rec.stats()
        s1.pop("stream_retries"), s2.pop("stream_retries")
        assert s1 == s2


def test_sharded_kill_at_every_chunk_boundary_bitwise(tmp_path):
    stream = _stream(160)
    ref = _sharded()
    snaps = _snapshots(ref, stream)         # 5 chunks of router_chunk=32
    n_chunks = len(snaps)
    assert n_chunks == 5
    q_ref = ref.query()
    ref_deg = {u: q_ref.degree(u) for u in _labels(stream)}
    for k in range(n_chunks + 1):
        d = str(tmp_path / f"k{k}")
        crashed = _sharded(d)
        with pytest.raises(inject.SimulatedCrash):
            inject.drive(crashed, stream, ckpt_every=2, kill_at_chunk=k)
        rec = _sharded(d)
        rec.recover()
        assert rec.stream_cursor == k * 32
        if k > 0:
            rec.flush()
            assert_leaves_equal(rec._ckpt_tree(), snaps[k - 1][0])
            ref_host, rec_host = snaps[k - 1][1], rec._ckpt_host()
            assert ref_host["h2label"] == rec_host["h2label"]
            np.testing.assert_array_equal(ref_host["drain_rounds"],
                                          rec_host["drain_rounds"])
        inject.drive(rec, stream, start=rec.stream_cursor)
        rec.flush()                         # drain the pipelined last chunk
        assert_leaves_equal(rec.state, ref.state)
        assert_leaves_equal(rec.intern, ref.intern)
        assert rec.host_label_map() == ref.host_label_map()
        s1, s2 = ref.stats(), rec.stats()
        s1.pop("stream_retries"), s2.pop("stream_retries")
        assert s1 == s2
        # serve/query answers identical post-recovery
        q = rec.query()
        assert {u: q.degree(u) for u in ref_deg} == ref_deg


def test_query_answers_survive_mid_stream_recovery(tmp_path):
    """Answers from the recovered engine at the kill point equal answers
    from an uninterrupted run over the same prefix."""
    stream = _stream(160)
    k, cut = 3, 3 * 32
    prefix = _sharded()
    prefix.process(stream[:cut])
    qp = prefix.query()
    want = {u: (qp.degree(u), sorted(qp.neighbors(u)))
            for u in _labels(stream[:cut])}

    d = str(tmp_path / "ck")
    crashed = _sharded(d)
    with pytest.raises(inject.SimulatedCrash):
        inject.drive(crashed, stream, ckpt_every=2, kill_at_chunk=k)
    rec = _sharded(d)
    rec.recover()
    q = rec.query()
    got = {u: (q.degree(u), sorted(q.neighbors(u))) for u in want}
    assert got == want


# --------------------------------------------------------------------------- #
# checkpoint faults
# --------------------------------------------------------------------------- #


def _crash_at(make, d, stream, k=5, ckpt_every=2):
    s = make(d)
    with pytest.raises(inject.SimulatedCrash):
        inject.drive(s, stream, ckpt_every=ckpt_every, kill_at_chunk=k)


def test_corrupt_newest_checkpoint_falls_back_one_epoch(tmp_path):
    stream = _stream(56)
    ref = _batched()
    inject.drive(ref, stream)
    d = str(tmp_path)
    _crash_at(_batched, d, stream)
    newest = inject.latest_checkpoint_step(d)
    inject.corrupt_checkpoint_arrays(d, newest)
    rec = _batched(d)
    info = rec.recover()
    assert info["step"] < newest            # checksum caught it, fell back
    assert info["rejected"] and "integrity" in info["rejected"][0]
    # journal retention reaches back to the SURVIVING epoch, so the replay
    # crosses the gap the corrupt checkpoint left
    assert info["replayed_chunks"] > 0
    inject.drive(rec, stream, start=rec.stream_cursor)
    assert_leaves_equal(rec.state, ref.state)


def test_all_checkpoints_corrupt_raises(tmp_path):
    stream = _stream(56)
    d = str(tmp_path)
    _crash_at(_batched, d, stream)
    for s in checkpointer.checkpoint_steps(d):
        inject.corrupt_checkpoint_arrays(d, s)
    with pytest.raises(FileNotFoundError, match="no restorable checkpoint"):
        _batched(d).recover()


def test_torn_staging_directory_is_ignored(tmp_path):
    stream = _stream(56)
    ref = _batched()
    inject.drive(ref, stream)
    d = str(tmp_path)
    _crash_at(_batched, d, stream)
    inject.tear_checkpoint_staging(d, inject.latest_checkpoint_step(d))
    rec = _batched(d)
    info = rec.recover()
    assert not info["rejected"]             # .tmp is invisible, not an error
    inject.drive(rec, stream, start=rec.stream_cursor)
    assert_leaves_equal(rec.state, ref.state)


def test_dropped_payload_file_detected(tmp_path):
    stream = _stream(56)
    d = str(tmp_path)
    _crash_at(_batched, d, stream)
    newest = inject.latest_checkpoint_step(d)
    inject.drop_checkpoint_file(d, newest, "host.pkl")
    rec = _batched(d)
    info = rec.recover()
    assert info["step"] < newest and info["rejected"]


# --------------------------------------------------------------------------- #
# journal faults
# --------------------------------------------------------------------------- #


def test_torn_journal_tail_recovers_valid_prefix(tmp_path):
    stream = _stream(56)
    ref = _batched()
    inject.drive(ref, stream)
    d = str(tmp_path)
    _crash_at(_batched, d, stream)          # 5 chunks journaled, ckpt at 4
    n = inject.journal_record_count(d)
    inject.truncate_journal_tail(d, nbytes=7)
    assert inject.journal_record_count(d) == n - 1
    rec = _batched(d)
    rec.recover()                           # lost exactly the torn chunk
    assert rec.stream_cursor == (5 - 1) * CFG.batch
    inject.drive(rec, stream, start=rec.stream_cursor)
    assert_leaves_equal(rec.state, ref.state)


def test_duplicated_journal_record_deduped(tmp_path):
    stream = _stream(56)
    ref = _batched()
    inject.drive(ref, stream)
    d = str(tmp_path)
    _crash_at(_batched, d, stream)
    inject.duplicate_journal_tail(d)
    rec = _batched(d)
    rec.recover()                           # replayed once, not twice
    assert rec.stream_cursor == 5 * CFG.batch
    inject.drive(rec, stream, start=rec.stream_cursor)
    assert_leaves_equal(rec.state, ref.state)


def test_fresh_run_resets_stale_journal(tmp_path):
    stream = _stream(56)
    d = str(tmp_path)
    _crash_at(_batched, d, stream)
    assert inject.journal_record_count(d) > 0
    fresh = _batched(d)                     # NOT recovered: a new run
    fresh.process(stream[:CFG.batch])
    assert inject.journal_record_count(d) == 1


# --------------------------------------------------------------------------- #
# manifest pins: refuse state from a different configuration
# --------------------------------------------------------------------------- #


def test_restore_refuses_different_policy_triple(tmp_path):
    d = str(tmp_path)
    s = _batched(d)
    s.process(_stream(16))
    s.save()
    other = BatchedSummarizer(
        EngineConfig(**{**CFG.manifest(), "commit": "threshold"}),
        checkpoint_dir=d)
    with pytest.raises(ConfigMismatchError, match="config"):
        other.restore()


def test_restore_refuses_different_n_shards_or_chunk(tmp_path):
    stream = _stream(64)
    d = str(tmp_path)
    s = _sharded(d)
    s.process(stream)
    s.save()
    with pytest.raises(ConfigMismatchError, match="n_shards"):
        _sharded(d, n_shards=4).restore()
    with pytest.raises(ConfigMismatchError, match="router_chunk"):
        _sharded(d, router_chunk=64).restore()


def test_restore_refuses_batched_into_sharded(tmp_path):
    d = str(tmp_path)
    s = _batched(d)
    s.process(_stream(16))
    s.save()
    with pytest.raises(ConfigMismatchError, match="tier"):
        _sharded(d).restore()


# --------------------------------------------------------------------------- #
# query-view fencing + retry loop
# --------------------------------------------------------------------------- #


def test_stale_query_view_fenced_after_restore(tmp_path):
    stream = _stream(160)
    d = str(tmp_path)
    s = _sharded(d)
    s.process(stream)
    s.save()
    lab = _labels(stream, 1)[0]
    stale = s.query()
    assert stale.degree(lab) >= 0           # live before the restore
    s.restore()
    with pytest.raises(RuntimeError, match="predates a checkpoint restore"):
        stale.degree(lab)
    assert s.query().degree(lab) >= 0       # a fresh view works


def test_run_stream_with_recovery_counts_retries(tmp_path):
    from repro.ft.resilience import run_stream_with_recovery
    stream = _stream(56)
    ref = _batched()
    inject.drive(ref, stream)

    class Flaky(BatchedSummarizer):
        crashes = [3, 5]                    # shared across rebuilds

        def process(self, changes):
            if self.crashes and self._journal_seq == self.crashes[0]:
                self.crashes.pop(0)
                raise RuntimeError("injected engine fault")
            super().process(changes)

    s = run_stream_with_recovery(
        lambda: Flaky(CFG, checkpoint_dir=str(tmp_path)),
        stream, str(tmp_path), ckpt_every=2, sleep=lambda _t: None)
    assert s.stats()["stream_retries"] == 2
    assert_leaves_equal(s.state, ref.state)
    # the final save() leaves a resumable epoch at end-of-stream
    rec = _batched(str(tmp_path))
    info = rec.recover()
    assert rec.stream_cursor == len(stream) and info["replayed_chunks"] == 0
    assert_leaves_equal(rec.state, ref.state)


def test_retry_loop_gives_up_past_max_failures(tmp_path):
    from repro.ft.resilience import run_stream_with_recovery

    class Doomed(BatchedSummarizer):
        def process(self, changes):
            raise RuntimeError("always fails")

    with pytest.raises(RuntimeError, match="always fails"):
        run_stream_with_recovery(
            lambda: Doomed(CFG, checkpoint_dir=str(tmp_path)),
            _stream(56), str(tmp_path), ckpt_every=2, max_failures=2,
            sleep=lambda _t: None)


# --------------------------------------------------------------------------- #
# elastic restore: checkpoint on 8 devices, recover on 1
# --------------------------------------------------------------------------- #


def test_checkpoint_on_8_devices_recovers_on_one(tmp_path):
    """A sharded run checkpoints mid-stream under 8 fake devices; this
    1-device process recovers it (same n_shards — the pinned quantity),
    continues, and must land bitwise on the 8-device run's final state.
    Topology is recorded in the manifest but NOT pinned: replica layout is
    bit-transparent per the standing differential bar."""
    d = str(tmp_path)
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = SRC
    code = textwrap.dedent(f"""
        import jax
        from repro.core.engine import EngineConfig, ShardedSummarizer
        from repro.ft import inject
        from repro.graph.streams import (edges_to_fully_dynamic_stream,
                                         sbm_edges)
        assert len(jax.devices()) == 8
        cfg = EngineConfig(**{CFG.manifest()!r})
        edges = sbm_edges(44, 4, 0.5, 0.05, seed=11)
        stream = edges_to_fully_dynamic_stream(
            edges, delete_prob=0.2, seed=11)[:160]
        s = ShardedSummarizer(cfg, n_shards=8, router_chunk=32,
                              checkpoint_dir={d!r})
        try:
            inject.drive(s, stream, ckpt_every=2, kill_at_chunk=3)
        except inject.SimulatedCrash:
            pass
        full = ShardedSummarizer(cfg, n_shards=8, router_chunk=32,
                                 checkpoint_dir={d!r} + "/full")
        inject.drive(full, stream)
        full.save()
        print("phi", full.phi)
    """)
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, env=env, timeout=900)
    assert out.returncode == 0, out.stderr[-3000:]

    stream = _stream(160)
    rec = _sharded(d, n_shards=8)           # 1 device, 8 shards
    info = rec.recover()
    assert info["replayed_chunks"] > 0      # journal tail crossed topologies
    inject.drive(rec, stream, start=rec.stream_cursor)
    rec.flush()

    # compare against the 8-device run's own final checkpoint, leaf by leaf
    like = rec._ckpt_tree()
    step8 = checkpointer.latest_valid_step(d + "/full")
    tree8 = checkpointer.restore(d + "/full", step8, like)
    assert_leaves_equal(like, tree8)
    meta8 = checkpointer.load_meta(d + "/full", step8)
    assert meta8["extra"]["manifest"]["n_devices"] == 8
    assert meta8["extra"]["cursor"] == rec.stream_cursor
    # the recovered engine serves queries
    q = rec.query()
    assert sum(q.degree(u) for u in _labels(stream)) > 0
