"""Per assigned architecture: reduced config, one real step on CPU,
output shapes + no NaNs (deliverable (f) smoke contract)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ASSIGNED, REGISTRY
from repro.data.synthetic import graph_batch, sasrec_batches
from repro.models import gnn as gnn_mod
from repro.models import sasrec as sasrec_mod
from repro.models import transformer as tfm
from repro.optim import adamw
from repro.train.step import make_train_step


@pytest.mark.parametrize("arch", [a for a in ASSIGNED
                                  if REGISTRY[a].family == "lm"])
def test_lm_arch_smoke(arch):
    cfg = REGISTRY[arch].make_smoke_config()
    params = tfm.init_transformer(cfg, jax.random.key(0))
    toks = jax.random.randint(jax.random.key(1), (2, 16), 0, cfg.vocab)
    logits = tfm.forward(params, toks, cfg)
    assert logits.shape == (2, 16, cfg.vocab_padded)
    assert bool(jnp.all(jnp.isfinite(logits.astype(jnp.float32))))
    # one train step
    opt_cfg = adamw.AdamWConfig()
    step = make_train_step(lambda p, t, l: tfm.loss_fn(p, t, l, cfg), opt_cfg)
    opt = adamw.init(params, opt_cfg)
    p2, _, m = step(params, opt, toks, toks)
    assert np.isfinite(float(m["loss"]))
    # one decode step
    cache = tfm.init_cache(cfg, 2, 8)
    lg, cache2 = tfm.decode_step(params, cache, toks[:, 0], cfg)
    assert lg.shape == (2, cfg.vocab_padded)
    assert int(cache2["len"]) == 1


@pytest.mark.parametrize("arch", [a for a in ASSIGNED
                                  if REGISTRY[a].family == "gnn"])
def test_gnn_arch_smoke(arch):
    cfg = REGISTRY[arch].make_smoke_config()
    needs_coords = cfg.arch in ("egnn", "dimenet")
    g = jax.tree.map(jnp.asarray, graph_batch(
        48, 160, cfg.d_in, cfg.n_classes, seed=0, with_coords=needs_coords))
    params = gnn_mod.init_gnn(cfg, jax.random.key(0))
    out = gnn_mod.gnn_forward(params, g, cfg)
    assert out.shape == (48, cfg.n_classes)
    assert bool(jnp.all(jnp.isfinite(out)))
    opt_cfg = adamw.AdamWConfig()
    step = make_train_step(lambda p, gb: gnn_mod.gnn_loss(p, gb, cfg), opt_cfg)
    opt = adamw.init(params, opt_cfg)
    p2, _, m = step(params, opt, g)
    assert np.isfinite(float(m["loss"]))


def test_recsys_arch_smoke():
    cfg = REGISTRY["sasrec"].make_smoke_config()
    params = sasrec_mod.init_sasrec(cfg, jax.random.key(0))
    x, pos, neg = next(sasrec_batches(cfg.n_items, 4, cfg.seq_len, seed=0))
    opt_cfg = adamw.AdamWConfig()
    step = make_train_step(
        lambda p, s, po, ne: sasrec_mod.train_loss(p, s, po, ne, cfg), opt_cfg)
    opt = adamw.init(params, opt_cfg)
    p2, _, m = step(params, opt, jnp.asarray(x), jnp.asarray(pos),
                    jnp.asarray(neg))
    assert np.isfinite(float(m["loss"]))
    scores = sasrec_mod.score_candidates(p2, jnp.asarray(x),
                                         jnp.arange(64), cfg)
    assert scores.shape == (4, 64)
    assert bool(jnp.all(jnp.isfinite(scores)))


def test_mosso_stream_smoke():
    from repro.core.engine import BatchedSummarizer
    from repro.graph.streams import (edges_to_fully_dynamic_stream, sbm_edges)
    cfg = REGISTRY["mosso-stream"].make_smoke_config()
    bs = BatchedSummarizer(cfg)
    edges = sbm_edges(32, 4, 0.5, 0.05, seed=0)
    bs.run(edges_to_fully_dynamic_stream(edges, seed=1))
    assert 0 < bs.compression_ratio() <= 1.0 + 1e-9
    assert bs.phi == bs.phi_recomputed()


def test_registry_covers_assignment():
    assert len(ASSIGNED) == 10
    cells = sum(len(REGISTRY[a].cells) for a in ASSIGNED)
    assert cells == 40, "assignment is 40 (arch x shape) cells"
