"""Online query engine: snapshot consistency and query-semantics contracts.

The tentpole bar for the serve layer (repro/serve/query.py):

* snapshot consistency — interleaving queries with stream chunks on the
  ShardedSummarizer (pipelined AND serial dispatch), every answer must
  correspond bitwise to some flushed epoch's edge set, never a torn
  intermediate; on the pipelined path snapshots must actually trail the
  write head (reads concurrent with an in-flight write chunk);
* unseen-label semantics — LookupError from all three operations, on
  both tiers, including labels the SUMMARIZER has seen but the pinned
  snapshot epoch has not;
* deleted-node semantics — a node whose edges were all removed stays
  queryable: empty neighbor set, degree 0, has_edge False;
* the sharded fan-out merge — at most one shard reports an edge, and it
  is the pair's ``shard_key`` owner.
"""
import itertools

import numpy as np
import pytest

from repro.core.engine import BatchedSummarizer, EngineConfig, ShardedSummarizer
from repro.graph.streams import edges_to_fully_dynamic_stream, sbm_edges


def _cfg(**kw):
    base = dict(n_cap=256, m_cap=2048, d_cap=48, sn_cap=32, c=8, batch=8,
                escape=0.3)
    base.update(kw)
    return EngineConfig(**base)


CHUNK = 48


def _stream(seed=7):
    edges = sbm_edges(40, 4, 0.5, 0.05, seed=seed)
    return edges_to_fully_dynamic_stream(edges, delete_prob=0.2,
                                         seed=seed + 1)


def _prefix(stream, n_chunks):
    """(live edge adjacency, seen labels) after the first n_chunks."""
    live, seen = set(), set()
    for (u, v, ins) in stream[:n_chunks * CHUNK]:
        seen.add(u)
        seen.add(v)
        e = (min(u, v), max(u, v))
        live.add(e) if ins else live.discard(e)
    adj = {}
    for (u, v) in live:
        adj.setdefault(u, set()).add(v)
        adj.setdefault(v, set()).add(u)
    return live, adj, seen


@pytest.mark.parametrize("pipeline", [True, False])
def test_sharded_query_snapshots_pin_flushed_epochs(pipeline):
    """Every snapshot's answers equal EXACTLY the edge set after
    ``view.epoch`` chunks — not the write head's, not any in-between
    state — checked for all three operations on every view, with views
    queried immediately after creation (while the pipelined router still
    has the next chunk's routing / this chunk's engine stage in flight)
    and again after the whole stream finished (CPU buffers are never
    donated, so held snapshots stay valid — docs/KNOWN_ISSUES.md)."""
    stream = _stream()
    cfg = _cfg(n_cap=128, m_cap=1024)
    ss = ShardedSummarizer(cfg, n_shards=2, router_chunk=CHUNK,
                           pipeline=pipeline)
    assert ss.pipeline == pipeline
    n_chunks = -(-len(stream) // CHUNK)

    views = []
    for k in range(n_chunks):
        ss.process(stream[k * CHUNK:(k + 1) * CHUNK])
        q = ss.query()
        views.append((q, k + 1))
        # answer a read immediately, concurrent with the in-flight chunk
        live, adj, seen = _prefix(stream, q.epoch)
        some = sorted(seen)[:6]
        assert q.neighbors_batch(some) == [adj.get(x, set()) for x in some]

    # epoch lag: pipelined snapshots trail the write head by the pending
    # routed chunk; serial snapshots sit exactly at it
    lags = [head - q.epoch for (q, head) in views]
    if pipeline:
        # one routed chunk always in flight -> every snapshot trails by 1
        assert all(lag == 1 for lag in lags), \
            f"pipelined reads should overlap a write: lags={lags}"
    else:
        assert all(lag == 0 for lag in lags)

    ss.flush()
    views.append((ss.query(), n_chunks))
    assert views[-1][0].epoch == n_chunks

    for q, _head in views:
        live, adj, seen = _prefix(stream, q.epoch)
        labs = q.seen_labels()
        # the snapshot sees exactly its epoch's label horizon
        assert set(labs) == seen
        assert q.neighbors_batch(labs) == [adj.get(x, set()) for x in labs]
        assert q.degree_batch(labs) == [len(adj.get(x, set())) for x in labs]
        pairs = list(itertools.combinations(sorted(seen)[:10], 2))
        if pairs:
            want = [(min(u, v), max(u, v)) in live for (u, v) in pairs]
            assert q.has_edge_batch(pairs) == want
        # labels first streamed AFTER the snapshot epoch are unseen HERE
        # even though the summarizer has long seen them
        _, _, seen_all = _prefix(stream, n_chunks)
        for lab in sorted(seen_all - seen)[:2]:
            with pytest.raises(LookupError):
                q.neighbors(lab)


def test_sharded_fanout_merges_owner_shard_answers():
    """Vertex-cut fan-out: a node's neighbors may span shards (union
    merge, degrees add exactly), while an edge lives in at most ONE shard
    — its ``shard_key`` owner, per ``has_edge_by_shard``."""
    stream = _stream(seed=21)
    ss = ShardedSummarizer(_cfg(n_cap=128, m_cap=1024), n_shards=2,
                           router_chunk=CHUNK).run(stream)
    ss.flush()                  # compare against the full stream's edges
    q = ss.query()
    live, adj, seen = _prefix(stream, -(-len(stream) // CHUNK))
    pairs = [tuple(e) for e in sorted(live)[:20]]
    present = q.has_edge_by_shard(pairs)
    assert present.shape[0] == 2
    assert (present.sum(axis=0) == 1).all()
    for j, (u, v) in enumerate(pairs):
        assert int(present[:, j].argmax()) == ss.shard_of(u, v)
    # both shards actually answered some neighbor queries
    some = sorted(seen)
    assert q.neighbors_batch(some) == [adj.get(x, set()) for x in some]
    assert q.degree_batch(some) == [len(adj.get(x, set())) for x in some]


@pytest.mark.parametrize("tier", ["batched", "sharded"])
def test_unseen_label_raises_lookup_error(tier):
    stream = _stream(seed=5)
    if tier == "batched":
        s = BatchedSummarizer(_cfg(n_cap=128, m_cap=1024)).run(stream)
    else:
        s = ShardedSummarizer(_cfg(n_cap=128, m_cap=1024),
                              n_shards=2).run(stream)
        s.flush()
    q = s.query()
    seen_lab = q.seen_labels()[0]
    for call in (lambda: q.neighbors("never-streamed"),
                 lambda: q.degree("never-streamed"),
                 lambda: q.has_edge("never-streamed", seen_lab),
                 lambda: q.has_edge(seen_lab, "never-streamed")):
        with pytest.raises(LookupError):
            call()


@pytest.mark.parametrize("tier", ["batched", "sharded"])
def test_deleted_node_answers_empty_not_lookup_error(tier):
    """A node whose every edge was deleted was still STREAMED: it answers
    the empty set / 0 / False rather than LookupError."""
    stream = [(0, 1, True), (0, 2, True), (1, 2, True),
              (0, 1, False), (0, 2, False)]
    cfg = _cfg(n_cap=64, m_cap=256, batch=4)
    if tier == "batched":
        s = BatchedSummarizer(cfg).run(stream)
    else:
        s = ShardedSummarizer(cfg, n_shards=2, router_chunk=8).run(stream)
        s.flush()
    q = s.query()
    assert q.neighbors(0) == set()
    assert q.degree(0) == 0
    assert q.has_edge(0, 1) is False
    assert q.has_edge(0, 0) is False        # self loops never exist
    assert q.neighbors(1) == {2}
    assert q.degree(2) == 1


def test_batched_snapshot_pins_label_horizon_and_state():
    """Batched tier: a snapshot answers its own epoch even after the
    summarizer moves on — later-streamed labels raise LookupError on the
    old view and resolve on a fresh one (CPU: no buffer donation)."""
    cfg = _cfg(n_cap=64, m_cap=256, batch=4)
    bs = BatchedSummarizer(cfg)
    bs.process([(0, 1, True), (1, 2, True), (2, 3, True), (3, 0, True)])
    q1 = bs.query()
    e1 = q1.epoch
    assert q1.neighbors(0) == {1, 3}
    bs.process([(0, 1, False), (4, 0, True), (4, 2, True), (1, 3, True)])
    assert bs.flush_epoch > e1
    # the old view still serves epoch e1's edge set
    assert q1.epoch == e1
    assert q1.neighbors(0) == {1, 3}
    assert q1.degree(1) == 2
    assert q1.has_edge(0, 1) is True
    with pytest.raises(LookupError):
        q1.neighbors(4)
    q2 = bs.query()
    assert q2.neighbors(0) == {3, 4}
    assert q2.has_edge(0, 1) is False
    assert q2.neighbors(4) == {0, 2}


def test_serve_summary_driver_reads_overlap_writes():
    """The launch driver runs verified read traffic concurrent with the
    write stream and reports the epoch lag that proves the overlap."""
    from repro.launch.serve_summary import serve_summary

    stream = _stream(seed=9)
    ss = ShardedSummarizer(_cfg(n_cap=128, m_cap=1024), n_shards=2,
                           router_chunk=CHUNK)
    out = serve_summary(ss, stream, reads_per_chunk=16, verify=True, seed=0)
    assert out["verified"] is True
    assert out["reads"] > 0
    assert out["reads_overlapped_writes"] is True   # pipelined: lag >= 1
    assert out["final_epoch"] == out["chunks"]
    assert out["max_lag"] >= 1


def test_query_batch_padding_is_invisible():
    """Query batches pad to powers of two on device; padded lanes must
    never leak into answers across a range of batch sizes."""
    stream = _stream(seed=3)
    bs = BatchedSummarizer(_cfg(n_cap=128, m_cap=1024)).run(stream)
    q = bs.query()
    live, adj, seen = _prefix(stream, 10 ** 6)
    labs = sorted(seen)
    for k in (1, 2, 3, 7, 8, 9, len(labs)):
        sub = labs[:k]
        assert q.neighbors_batch(sub) == [adj.get(x, set()) for x in sub]
        assert q.degree_batch(sub) == [len(adj.get(x, set())) for x in sub]
