"""Differential engine-vs-reference tests.

The same synthetic FD stream (graph/streams.py) drives the Tier-A
DynamicSummary and the Tier-B BatchedSummarizer side by side; after every
engine batch both must (a) satisfy the phi == |P| + |C+| + |C-| invariant
and (b) decode losslessly back to the exact live edge set.  This is the
standing verification bar for engine changes (ROADMAP open items).
"""
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:      # container has no hypothesis; deterministic shim
    from repro.testing.proptest import given, settings, strategies as st

from repro.core.engine import BatchedSummarizer, EngineConfig, ShardedSummarizer
from repro.core.reference.dynamic_summary import DynamicSummary
from repro.core.summary import pair_key
from repro.graph.streams import edges_to_fully_dynamic_stream, sbm_edges

from conftest import ground_truth_edges


def _cfg(**kw):
    base = dict(n_cap=256, m_cap=2048, d_cap=48, sn_cap=32, c=8, batch=16,
                escape=0.3)
    base.update(kw)
    return EngineConfig(**base)


@pytest.mark.parametrize("seed", [0, 1])
def test_differential_tier_a_vs_tier_b_batchwise(seed):
    edges = sbm_edges(40, 4, 0.55, 0.04, seed=seed)
    stream = edges_to_fully_dynamic_stream(edges, delete_prob=0.15,
                                           seed=seed + 1)
    cfg = _cfg()
    bs = BatchedSummarizer(cfg)
    ref = DynamicSummary()
    live = set()

    for off in range(0, len(stream), cfg.batch):
        chunk = stream[off:off + cfg.batch]
        bs.process(chunk)
        for (u, v, ins) in chunk:
            e = (min(u, v), max(u, v))
            if ins:
                ref.insert(*e)
                live.add(e)
            else:
                ref.delete(*e)
                live.discard(e)
        tag = f"seed={seed} off={off}"
        # (a) phi invariant in BOTH tiers, after every batch
        ref_mat = ref.materialize()
        assert ref.phi == ref_mat.phi == ref.phi_recomputed(), tag
        eng_mat = bs.materialize()      # also asserts eab vs live edges
        assert bs.phi == eng_mat.phi == bs.phi_recomputed(), tag
        # (b) both decode losslessly to the exact live edge set
        assert ref_mat.decode_edges() == live, tag
        eng_live = {pair_key(bs._ids[u], bs._ids[v]) for (u, v) in live}
        assert eng_mat.decode_edges() == eng_live, tag

    assert live == ground_truth_edges(stream)
    # both tiers end bounded by |E| (phi <= |E| under the optimal encoding)
    assert ref.phi <= len(live)
    assert bs.phi <= len(live)


def test_differential_final_phi_within_band():
    """Tier-B phi lands in a band around Tier-A on the same stream: both are
    randomized greedy searches over the same objective."""
    edges = sbm_edges(48, 4, 0.6, 0.03, seed=5)
    stream = edges_to_fully_dynamic_stream(edges, delete_prob=0.1, seed=6)
    bs = BatchedSummarizer(_cfg(c=12)).run(stream)
    ref = DynamicSummary()
    for (u, v, ins) in stream:
        (ref.insert if ins else ref.delete)(u, v)
    n_live = len(ground_truth_edges(stream))
    assert 0 < bs.phi <= n_live
    assert ref.phi == n_live    # no moves: reference stays at trivial encoding
    assert bs.phi <= ref.phi    # the trial engine may only improve on trivial


@settings(max_examples=5, deadline=None)
@given(st.integers(0, 9999), st.integers(2, 4))
def test_predicated_step_matches_reference_batchwise_property(seed, deg):
    """Property (PR 5): for any stream seed/density, the PREDICATED trial
    engine — Alg. 1 as cond-free masked data flow — satisfies the Tier-A
    reference contract batchwise: the phi invariant holds in both tiers
    after every batch and both decode losslessly to the exact live edge
    set.  One fixed config, so every example reuses one compiled step."""
    edges = sbm_edges(28, deg, 0.5, 0.06, seed=seed)
    stream = edges_to_fully_dynamic_stream(edges, delete_prob=0.2,
                                           seed=seed + 1)
    cfg = _cfg(n_cap=128, m_cap=1024, batch=8, c=6)
    bs = BatchedSummarizer(cfg)
    ref = DynamicSummary()
    live = set()
    for off in range(0, len(stream), cfg.batch):
        chunk = stream[off:off + cfg.batch]
        bs.process(chunk)
        for (u, v, ins) in chunk:
            e = (min(u, v), max(u, v))
            if ins:
                ref.insert(*e)
                live.add(e)
            else:
                ref.delete(*e)
                live.discard(e)
        tag = f"seed={seed} off={off}"
        ref_mat = ref.materialize()
        assert ref.phi == ref_mat.phi == ref.phi_recomputed(), tag
        eng_mat = bs.materialize()      # also asserts eab vs live edges
        assert bs.phi == eng_mat.phi == bs.phi_recomputed(), tag
        assert ref_mat.decode_edges() == live, tag
        eng_live = {pair_key(bs._ids[u], bs._ids[v]) for (u, v) in live}
        assert eng_mat.decode_edges() == eng_live, tag
    assert live == ground_truth_edges(stream)


def _count_primitives(jaxpr, name: str) -> int:
    """Occurrences of a primitive at any nesting depth (incl. inside
    pallas_call kernel jaxprs, which live in eqn params)."""
    import jax.core as jc

    def subjaxprs(val):
        if isinstance(val, jc.ClosedJaxpr):
            return [val.jaxpr]
        if isinstance(val, jc.Jaxpr):
            return [val]
        if isinstance(val, (list, tuple)):
            return [s for v in val for s in subjaxprs(v)]
        return []

    n = 0
    for eqn in jaxpr.eqns:
        if eqn.primitive.name == name:
            n += 1
        for val in eqn.params.values():
            for sub in subjaxprs(val):
                n += _count_primitives(sub, name)
    return n


@pytest.mark.parametrize("trial_backend", ["xla", "pallas"])
def test_trial_engine_compiles_cond_free(trial_backend):
    """Acceptance tripwire (PR 5, extended to the probe-kernel backend in
    PR 6): the lowered engine step must contain no ``cond`` primitive at
    any nesting depth — predication (masked writes + 0/1-trip while
    regions) is the only control flow besides scan/while, under BOTH
    probe backends and both ``dense`` lowerings.  The pallas path must
    actually contain probe-kernel launches; the xla path must contain
    none."""
    import numpy as np

    import jax
    from repro.core.engine.hashtable import trial_backend_scope
    from repro.core.engine.state import new_state
    from repro.core.engine.trial import step_fn

    cfg = _cfg(n_cap=64, m_cap=256, d_cap=8, sn_cap=8, c=3, batch=4)

    u = np.zeros(4, np.int32)
    for dense in (False, True):
        with trial_backend_scope(trial_backend):
            closed = jax.make_jaxpr(
                lambda s, a, b, c: step_fn(s, a, b, c, cfg, dense))(
                    new_state(cfg), u, u + 1, u > 0)
        tag = f"backend={trial_backend} dense={dense}"
        assert _count_primitives(closed.jaxpr, "cond") == 0, \
            f"cond found ({tag})"
        n_pallas = _count_primitives(closed.jaxpr, "pallas_call")
        if trial_backend == "pallas":
            assert n_pallas > 0, f"no probe kernel launch traced ({tag})"
        else:
            assert n_pallas == 0, f"unexpected pallas_call ({tag})"


def test_policy_matrix_compiles_cond_free():
    """Acceptance tripwire (PR 8): EVERY proposal x objective x commit
    triple lowers with zero ``cond`` primitives at any nesting depth, under
    both ``dense`` lowerings — policy dispatch is trace-time Python, so no
    variant may smuggle data-dependent control flow into the step."""
    import itertools

    import numpy as np

    import jax
    from repro.core.engine.state import (COMMIT_RULES, OBJECTIVES, PROPOSALS,
                                         new_state)
    from repro.core.engine.trial import step_fn

    u = np.zeros(4, np.int32)
    for prop, obj, com in itertools.product(PROPOSALS, OBJECTIVES,
                                            COMMIT_RULES):
        cfg = _cfg(n_cap=64, m_cap=256, d_cap=8, sn_cap=8, c=3, batch=4,
                   proposal=prop, objective=obj, commit=com,
                   commit_margin=1, weight_levels=3)
        for dense in (False, True):
            closed = jax.make_jaxpr(
                lambda s, a, b, c: step_fn(s, a, b, c, cfg, dense))(
                    new_state(cfg), u, u + 1, u > 0)
            tag = f"triple=({prop},{obj},{com}) dense={dense}"
            assert _count_primitives(closed.jaxpr, "cond") == 0, \
                f"cond found ({tag})"


def test_magsdm_engine_matches_reference_batchwise():
    """PR 8 variant bar, proposal="magsdm": the engine's modal-supernode
    candidate scheme runs against its own host reference (MoSSoMags, WITH
    trials) on an identical FD stream; after every batch both tiers satisfy
    the phi invariant and decode losslessly to the exact live edge set."""
    from repro.core.reference import MoSSoMags

    edges = sbm_edges(40, 4, 0.55, 0.04, seed=31)
    stream = edges_to_fully_dynamic_stream(edges, delete_prob=0.15, seed=32)
    cfg = _cfg(proposal="magsdm")
    bs = BatchedSummarizer(cfg)
    algo = MoSSoMags(seed=0, c=24)
    live = set()

    for off in range(0, len(stream), cfg.batch):
        chunk = stream[off:off + cfg.batch]
        bs.process(chunk)
        for (u, v, ins) in chunk:
            algo.process(u, v, ins)
            e = (min(u, v), max(u, v))
            (live.add if ins else live.discard)(e)
        tag = f"off={off}"
        ref_mat = algo.s.materialize()
        assert algo.s.phi == ref_mat.phi == algo.s.phi_recomputed(), tag
        eng_mat = bs.materialize()      # also asserts eab vs live edges
        assert bs.phi == eng_mat.phi == bs.phi_recomputed(), tag
        assert ref_mat.decode_edges() == live, tag
        eng_live = {pair_key(bs._ids[u], bs._ids[v]) for (u, v) in live}
        assert eng_mat.decode_edges() == eng_live, tag

    assert live == ground_truth_edges(stream)
    assert bs.phi <= len(live) and algo.s.phi <= len(live)
    assert int(bs.state.n_accept) > 0      # the variant actually moved nodes


def test_weighted_engine_matches_reference_batchwise():
    """PR 8 variant bar, objective="weighted": the engine (hashed weights
    over DENSE interned ids) runs against its own host reference — a
    WeightedDynamicSummary weighing caller labels through the intern map,
    so both tiers price the same node identically.  After every batch both
    satisfy the weighted phi invariant (live phi == materialized
    ``phi_weighted`` == refolded pair table) and decode losslessly: weights
    move encoding choices, never the edge set."""
    from repro.core.reference import WeightedDynamicSummary, host_node_weight

    levels = 3
    edges = sbm_edges(40, 4, 0.55, 0.04, seed=33)
    stream = edges_to_fully_dynamic_stream(edges, delete_prob=0.15, seed=34)
    # the engine interns labels in first-appearance order; replaying the
    # stream reproduces the dense-id map before the engine exists
    interned = {}
    for (u, v, _) in stream:
        for x in (u, v):
            interned.setdefault(x, len(interned))
    w_label = lambda lab: host_node_weight(interned[lab], levels)
    w_dense = lambda d: host_node_weight(d, levels)

    cfg = _cfg(objective="weighted", weight_levels=levels)
    bs = BatchedSummarizer(cfg)
    ref = WeightedDynamicSummary(weight_levels=levels, node_weight=w_label)
    live = set()

    for off in range(0, len(stream), cfg.batch):
        chunk = stream[off:off + cfg.batch]
        bs.process(chunk)
        for (u, v, ins) in chunk:
            e = (min(u, v), max(u, v))
            if ins:
                ref.insert(*e)
                live.add(e)
            else:
                ref.delete(*e)
                live.discard(e)
        tag = f"off={off}"
        ref_mat = ref.materialize()
        assert ref.phi == ref_mat.phi_weighted(ref._w) \
            == ref.phi_recomputed(), tag
        eng_mat = bs.materialize()  # asserts eab vs live edges + weab drift
        assert bs.phi == eng_mat.phi_weighted(w_dense) \
            == bs.phi_recomputed(), tag
        assert ref_mat.decode_edges() == live, tag
        eng_live = {pair_key(bs._ids[u], bs._ids[v]) for (u, v) in live}
        assert eng_mat.decode_edges() == eng_live, tag

    assert live == ground_truth_edges(stream)
    # the precomputed intern replay really is the engine's dense-id map —
    # the premise that made w_label and w_dense price nodes identically
    assert interned == bs._ids


def test_query_vs_decode_under_nondefault_policies():
    """The query path is policy-INDEPENDENT by construction: answers always
    equal the listed edge set, whatever produced it.  Pin that under the
    fully non-default triple — after every batch, neighbors/degree/has_edge
    from the compressed state equal the decode oracle."""
    import itertools

    cfg = _cfg(n_cap=128, m_cap=1024, batch=16, c=6, proposal="magsdm",
               objective="weighted", weight_levels=3, commit="threshold",
               commit_margin=0)
    edges = sbm_edges(36, 4, 0.55, 0.05, seed=35)
    stream = edges_to_fully_dynamic_stream(edges, delete_prob=0.2, seed=36)
    bs = BatchedSummarizer(cfg)

    for off in range(0, len(stream), cfg.batch):
        bs.process(stream[off:off + cfg.batch])
        tag = f"off={off}"
        q = bs.query()
        dec = {pair_key(bs._rev[a], bs._rev[b])
               for (a, b) in bs.materialize().decode_edges()}
        adj = _adj_from_edges(dec)
        labs = q.seen_labels()
        for lab, nb, dg in zip(labs, q.neighbors_batch(labs),
                               q.degree_batch(labs)):
            want = adj.get(lab, set())
            assert nb == want, f"neighbors({lab}) {tag}"
            assert dg == len(want), f"degree({lab}) {tag}"
        pairs = list(itertools.combinations(labs[:12], 2))
        for (u, v), got in zip(pairs, q.has_edge_batch(pairs)):
            assert got == (pair_key(u, v) in dec), f"has_edge({u},{v}) {tag}"


def test_pallas_step_bitwise_equals_xla_step():
    """The probe-kernel backend is not 'close': on an identical stream the
    pallas- and xla-backed engines must end in leaf-bitwise IDENTICAL
    states — the probe sequence is the on-device table layout, so any
    divergence is corruption, not noise."""
    import jax
    import numpy as np

    edges = sbm_edges(30, 3, 0.5, 0.06, seed=21)
    stream = edges_to_fully_dynamic_stream(edges, delete_prob=0.2, seed=22)
    cfg = _cfg(n_cap=128, m_cap=1024, batch=8, c=6)
    bx = BatchedSummarizer(cfg, trial_backend="xla").run(stream)
    bp = BatchedSummarizer(cfg, trial_backend="pallas").run(stream)
    assert bx.phi == bp.phi
    for lx, lp in zip(jax.tree.leaves(bx.state), jax.tree.leaves(bp.state)):
        np.testing.assert_array_equal(np.asarray(lx), np.asarray(lp))


@settings(max_examples=3, deadline=None)
@given(st.integers(0, 9999), st.integers(2, 4))
def test_pallas_step_matches_reference_batchwise_property(seed, deg):
    """Property (PR 6): the PALLAS-backed trial engine — batched probes
    fused into ``kernels/ht_probe.py`` launches, interpret mode on CPU —
    satisfies the same Tier-A reference contract batchwise as the
    predicated XLA engine: the phi invariant holds in both tiers after
    every batch and both decode losslessly to the exact live edge set.
    One fixed config, so every example reuses one compiled step."""
    edges = sbm_edges(28, deg, 0.5, 0.06, seed=seed)
    stream = edges_to_fully_dynamic_stream(edges, delete_prob=0.2,
                                           seed=seed + 1)
    cfg = _cfg(n_cap=128, m_cap=1024, batch=8, c=6)
    bs = BatchedSummarizer(cfg, trial_backend="pallas")
    ref = DynamicSummary()
    live = set()
    for off in range(0, len(stream), cfg.batch):
        chunk = stream[off:off + cfg.batch]
        bs.process(chunk)
        for (u, v, ins) in chunk:
            e = (min(u, v), max(u, v))
            if ins:
                ref.insert(*e)
                live.add(e)
            else:
                ref.delete(*e)
                live.discard(e)
        tag = f"seed={seed} off={off}"
        ref_mat = ref.materialize()
        assert ref.phi == ref_mat.phi == ref.phi_recomputed(), tag
        eng_mat = bs.materialize()      # also asserts eab vs live edges
        assert bs.phi == eng_mat.phi == bs.phi_recomputed(), tag
        assert ref_mat.decode_edges() == live, tag
        eng_live = {pair_key(bs._ids[u], bs._ids[v]) for (u, v) in live}
        assert eng_mat.decode_edges() == eng_live, tag
    assert live == ground_truth_edges(stream)


def _adj_from_edges(edge_set):
    adj = {}
    for (u, v) in edge_set:
        adj.setdefault(u, set()).add(v)
        adj.setdefault(v, set()).add(u)
    return adj


@pytest.mark.parametrize("trial_backend", ["xla", "pallas"])
def test_query_vs_decode_differential_batched(trial_backend):
    """Standing-bar extension (PR 7): on an FD stream, after EVERY batch,
    neighbors/degree/has_edge answered from the compressed engine state —
    membership -> superedge scan -> correction patch-up, no decompression
    — must exactly equal answers computed from ``decode_edges()``, and a
    third, independent host walk of the materialized output (the
    :class:`SummaryQueryOracle`) must agree with both; under both probe
    backends."""
    import itertools

    from repro.core.reference import SummaryQueryOracle

    edges = sbm_edges(36, 4, 0.55, 0.05, seed=3)
    stream = edges_to_fully_dynamic_stream(edges, delete_prob=0.2, seed=4)
    cfg = _cfg(n_cap=128, m_cap=1024, batch=16, c=6)
    bs = BatchedSummarizer(cfg, trial_backend=trial_backend)

    for off in range(0, len(stream), cfg.batch):
        bs.process(stream[off:off + cfg.batch])
        tag = f"backend={trial_backend} off={off}"
        q = bs.query()
        mat = bs.materialize()
        # the decode oracle, mapped back to caller labels
        dec = {pair_key(bs._rev[a], bs._rev[b])
               for (a, b) in mat.decode_edges()}
        adj = _adj_from_edges(dec)
        oracle = SummaryQueryOracle(mat)       # host Lemma-1 walk, eng ids
        labs = q.seen_labels()
        for lab, nb, dg in zip(labs, q.neighbors_batch(labs),
                               q.degree_batch(labs)):
            want = adj.get(lab, set())
            assert nb == want, f"neighbors({lab}) {tag}"
            assert dg == len(want), f"degree({lab}) {tag}"
            assert oracle.neighbors(bs._ids[lab]) == \
                {bs._ids[w] for w in want}, f"oracle({lab}) {tag}"
        pairs = list(itertools.combinations(labs[:12], 2))
        for (u, v), got in zip(pairs, q.has_edge_batch(pairs)):
            want = pair_key(u, v) in dec
            assert got == want, f"has_edge({u},{v}) {tag}"
            assert oracle.has_edge(bs._ids[u], bs._ids[v]) == want, tag


def test_query_vs_decode_differential_sharded():
    """Standing-bar extension (PR 7), sharded tier: after every routed
    chunk the flushed snapshot's query answers must exactly equal the
    union-of-parts ``decode_edges()`` (both in caller-label space), and
    the host oracle over the merged output must agree.  ``replica_exec``
    and the probe backend come from the environment, so the CI
    router-stress matrix runs this under all four combinations."""
    import itertools

    from repro.core.reference import SummaryQueryOracle

    edges = sbm_edges(40, 4, 0.5, 0.05, seed=13)
    stream = edges_to_fully_dynamic_stream(edges, delete_prob=0.2, seed=14)
    cfg = _cfg(n_cap=128, m_cap=1024, batch=8)
    ss = ShardedSummarizer(cfg, n_shards=2, router_chunk=64)

    for off in range(0, len(stream), ss.router_chunk):
        ss.process(stream[off:off + ss.router_chunk])
        tag = f"off={off}"
        mat = ss.materialize()     # sync point: flushes the pipeline
        q = ss.query()             # snapshot == the flushed epoch
        assert q.epoch == ss.flush_epoch
        dec = mat.decode_edges()   # caller-label pairs (union of parts)
        adj = _adj_from_edges(dec)
        oracle = SummaryQueryOracle(mat)
        labs = q.seen_labels()
        for lab, nb, dg in zip(labs, q.neighbors_batch(labs),
                               q.degree_batch(labs)):
            want = adj.get(lab, set())
            assert nb == want, f"neighbors({lab}) {tag}"
            assert dg == len(want), f"degree({lab}) {tag}"
            assert oracle.neighbors(lab) == want, f"oracle({lab}) {tag}"
        pairs = list(itertools.combinations(labs[:12], 2))
        for (u, v), got in zip(pairs, q.has_edge_batch(pairs)):
            want = pair_key(u, v) in dec
            assert got == want, f"has_edge({u},{v}) {tag}"
            assert oracle.has_edge(u, v) == want, tag


def test_sharded_summarizer_matches_ground_truth_single_device():
    """ShardedSummarizer with 2 logical partitions on however many devices
    the test process has (1 in tier-1 runs): lossless union decode, phi
    additivity, and agreement of the invariants per shard."""
    edges = sbm_edges(44, 4, 0.5, 0.05, seed=11)
    stream = edges_to_fully_dynamic_stream(edges, delete_prob=0.2, seed=12)
    cfg = _cfg(n_cap=128, m_cap=1024, batch=8)
    ss = ShardedSummarizer(cfg, n_shards=2)
    assert ss.n_shards == 2
    ss.run(stream)

    truth = ground_truth_edges(stream)
    assert ss.live_edges() == truth
    out = ss.materialize()
    assert len(out.shards) == 2
    assert out.decode_edges() == truth
    assert out.phi == ss.phi == sum(ss.shard_phis()) == ss.phi_recomputed()
    assert ss.num_edges == len(truth)
    assert 0 < ss.phi <= len(truth)
    # both partitions actually carried load
    assert all(int(n) > 0 for n in
               __import__("numpy").asarray(ss.state.num_edges))
