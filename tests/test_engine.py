"""Tier-B batched engine: hash tables, step invariants, quality band."""
import random

import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:      # container has no hypothesis; deterministic shim
    from repro.testing.proptest import given, settings, strategies as st

from repro.core.engine import BatchedSummarizer, EngineConfig
from repro.core.engine.hashtable import (ht_add, ht_delete, ht_load,
                                         ht_lookup, ht_lookup_batch, ht_new,
                                         ht_rebuild, ht_set)
from repro.core.reference import MoSSo
from repro.graph.streams import edges_to_fully_dynamic_stream, sbm_edges

from conftest import ground_truth_edges


@settings(max_examples=20, deadline=None)
@given(st.lists(st.tuples(st.integers(0, 5), st.integers(0, 5),
                          st.integers(-2, 2)), max_size=60))
def test_hashtable_matches_dict(ops):
    ht = ht_new(64)
    model = {}
    for (a, b, d) in ops:
        if d == 0:
            model.pop((a, b), None)
            ht = ht_delete(ht, a, b)
        else:
            ht, nv = ht_add(ht, a, b, d, remove_if_zero=True)
            new = model.get((a, b), 0) + d
            assert int(nv) == new
            if new == 0:
                model.pop((a, b), None)
            else:
                model[(a, b)] = new
    for a in range(6):
        for b in range(6):
            assert int(ht_lookup(ht, a, b)) == model.get((a, b), 0)
    ht2 = ht_rebuild(ht)
    for (a, b), v in model.items():
        assert int(ht_lookup(ht2, a, b)) == v


def test_hashtable_batch_lookup():
    ht = ht_new(32)
    for i in range(8):
        ht = ht_set(ht, i, i * 2, i + 100)
    k1 = jnp.arange(10, dtype=jnp.int32)
    got = ht_lookup_batch(ht, k1, k1 * 2, default=-7)
    expect = [i + 100 for i in range(8)] + [-7, -7]
    assert list(map(int, got)) == expect


@pytest.fixture(scope="module")
def engine_cfg():
    return EngineConfig(n_cap=512, m_cap=4096, d_cap=48, sn_cap=32, c=12,
                        batch=16, escape=0.25)


@pytest.fixture(scope="module")
def engine_run(engine_cfg):
    edges = sbm_edges(48, 4, 0.6, 0.02, seed=1)
    stream = edges_to_fully_dynamic_stream(edges, delete_prob=0.2, seed=2)
    bs = BatchedSummarizer(engine_cfg)
    bs.run(stream)
    return bs, stream


def test_engine_lossless(engine_run):
    bs, stream = engine_run
    out = bs.materialize()        # materialize() itself asserts eab vs edges
    gt = set()
    for (u, v, ins) in stream:
        a, b = bs._ids[u], bs._ids[v]
        e = (min(a, b), max(a, b))
        gt.add(e) if ins else gt.discard(e)
    assert out.decode_edges() == gt


def test_engine_phi_consistent(engine_run):
    bs, _ = engine_run
    assert bs.phi == bs.phi_recomputed() == bs.materialize().phi
    assert 0 < bs.compression_ratio() <= 1.0 + 1e-9


def test_engine_accepts_moves(engine_run):
    bs, _ = engine_run
    st = bs.stats()
    assert st["accepted"] > 0
    assert st["trials"] > st["accepted"]


def test_engine_quality_close_to_reference(engine_run):
    """Tier-B compression within a band of the faithful Tier-A MoSSo."""
    bs, stream = engine_run
    ref = MoSSo(seed=3, c=12, escape=0.25)
    ref.run(stream)
    assert bs.compression_ratio() <= ref.s.compression_ratio() * 1.25 + 0.05


def test_engine_phi_never_negative_and_bounded(engine_run):
    bs, _ = engine_run
    assert 0 <= bs.phi <= bs.num_edges


def test_engine_table_load_headroom(engine_run):
    bs, _ = engine_run
    for name in ("adj", "epos", "eab", "snadj", "snpos"):
        load = float(ht_load(getattr(bs.state, name)))
        assert load < 0.6, f"{name} over-loaded: {load}"


def test_engine_compaction_preserves_state(engine_cfg):
    """Tombstone compaction is a pure rewrite: phi, edges, outputs equal."""
    from repro.graph.streams import barabasi_albert_edges
    edges = barabasi_albert_edges(60, 3, seed=9)
    stream = edges_to_fully_dynamic_stream(edges, delete_prob=0.4, seed=10)
    bs = BatchedSummarizer(engine_cfg)
    bs.run(stream)
    before = (bs.phi, bs.num_edges, bs.live_edges())
    pressure0 = bs.table_pressure()
    bs.maybe_compact(threshold=0.0)     # force-rebuild every table
    after = (bs.phi, bs.num_edges, bs.live_edges())
    assert before == after
    assert bs.phi == bs.phi_recomputed()
    # compaction never increases occupied-slot pressure
    for name, p in bs.table_pressure().items():
        assert p <= pressure0[name] + 1e-9
    # and the engine keeps working afterwards
    bs.process([(10_000, 10_001, True)])
    assert bs.num_edges == before[1] + 1


# --------------------------------------------------------------------------- #
# sampling primitives: exact-uniformity fixes (PR 3)
# --------------------------------------------------------------------------- #


def test_rnd_below_is_lemire_multiply_shift():
    """rnd_below must implement (u64(x) * n) >> 32 exactly — the modulo
    form it replaced skews neighbor/candidate picks toward small indices."""
    from repro.core.engine.ops import rnd_below, rnd_u32
    rng = np.random.default_rng(0)
    seeds = rng.integers(0, 2**32, size=200, dtype=np.uint32)
    ctrs = rng.integers(0, 2**32, size=200, dtype=np.uint32)
    ns = rng.integers(1, 2**31 - 1, size=200, dtype=np.int64)
    got = jax.vmap(rnd_below)(jnp.asarray(seeds), jnp.asarray(ctrs),
                              jnp.asarray(ns.astype(np.int32)))
    draws = jax.vmap(rnd_u32)(jnp.asarray(seeds), jnp.asarray(ctrs))
    want = (np.asarray(draws).astype(np.uint64) * ns.astype(np.uint64)) >> 32
    np.testing.assert_array_equal(np.asarray(got).astype(np.uint64), want)
    assert (np.asarray(got) >= 0).all() and (np.asarray(got) < ns).all()


def test_rnd_below_uniform_over_small_range():
    """Empirical uniformity for a non-power-of-2 n (the modulo-bias case)."""
    from repro.core.engine.ops import rnd_below
    n, m = 7, 70_000
    got = np.asarray(jax.vmap(
        lambda c: rnd_below(jnp.uint32(12345), c, jnp.int32(n)))(
            jnp.arange(m, dtype=jnp.uint32)))
    counts = np.bincount(got, minlength=n)
    expected = m / n
    # 5-sigma band around a binomial count
    sigma = (expected * (1 - 1 / n)) ** 0.5
    assert (np.abs(counts - expected) < 5 * sigma).all(), counts


def test_rnd_below_empty_range_guard():
    from repro.core.engine.ops import rnd_below
    assert int(rnd_below(jnp.uint32(1), jnp.uint32(2), jnp.int32(0))) == 0


def test_mixhash_uses_full_31_bit_space():
    """The 0x7FFFFFFE mask cleared the low bit (halving the cluster-id
    space, doubling spurious CP(y) collisions); the fix keeps odd ids and
    only remaps the single NO_CLUSTER collision."""
    from repro.core.engine.ops import mixhash
    from repro.core.engine.state import NO_CLUSTER
    h = np.asarray(mixhash(jnp.arange(4096, dtype=jnp.int32)))
    assert (h >= 0).all()
    assert (h != int(NO_CLUSTER)).all()        # sentinel never produced
    odd = int((h & 1).sum())
    assert 0.4 < odd / len(h) < 0.6, odd       # low bit carries entropy again
