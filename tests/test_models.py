"""Model-layer tests: transformer decode agreement, GNN/sasrec behaviour."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.data.synthetic import graph_batch, lm_batches, sasrec_batches
from repro.models.gnn import GNNConfig, gnn_forward, gnn_loss, init_gnn
from repro.models.sasrec import (SASRecConfig, init_sasrec, score_candidates,
                                 serve_topk, train_loss)
from repro.models.transformer import (TransformerConfig, decode_step, forward,
                                      init_cache, init_transformer, loss_fn)


def _tiny_cfg(attn="gqa", moe=0):
    return TransformerConfig(
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2 if attn == "gqa" else 4,
        d_head=16, d_ff=128, vocab=97, attn=attn, n_experts=moe, top_k=2,
        capacity_factor=8.0, q_lora=32, kv_lora=24, rope_dim=8, nope_dim=16,
        v_head_dim=16, remat=False, param_dtype=jnp.float32,
        compute_dtype=jnp.float32)


@pytest.mark.parametrize("attn,moe", [("gqa", 0), ("gqa", 8),
                                      ("mla", 0), ("mla", 8)])
def test_decode_matches_forward(attn, moe):
    cfg = _tiny_cfg(attn, moe)
    params = init_transformer(cfg, jax.random.key(0))
    toks = jax.random.randint(jax.random.key(1), (2, 8), 0, cfg.vocab)
    logits = forward(params, toks, cfg)
    cache = init_cache(cfg, 2, 16)
    outs = []
    for t in range(8):
        lg, cache = decode_step(params, cache, toks[:, t], cfg)
        outs.append(lg)
    err = float(jnp.max(jnp.abs(jnp.stack(outs, 1) - logits)))
    assert err < 2e-3, f"decode diverged from forward: {err}"


def test_forward_shapes_and_finite():
    cfg = _tiny_cfg()
    params = init_transformer(cfg, jax.random.key(0))
    toks = jax.random.randint(jax.random.key(1), (3, 12), 0, cfg.vocab)
    logits = forward(params, toks, cfg)
    assert logits.shape == (3, 12, cfg.vocab_padded)
    assert bool(jnp.all(jnp.isfinite(logits)))


def test_lm_loss_decreases_with_training():
    from repro.optim import adamw
    from repro.train.step import make_train_step
    cfg = _tiny_cfg()
    params = init_transformer(cfg, jax.random.key(0))
    opt_cfg = adamw.AdamWConfig(lr=3e-3, warmup_steps=5, total_steps=100)
    step = jax.jit(make_train_step(
        lambda p, t, l: loss_fn(p, t, l, cfg), opt_cfg))
    opt = adamw.init(params, opt_cfg)
    data = lm_batches(cfg.vocab, 8, 32, seed=0)
    losses = []
    for _ in range(30):
        x, y = next(data)
        params, opt, m = step(params, opt, jnp.asarray(x), jnp.asarray(y))
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0] * 0.9, losses[::10]


def test_microbatched_grads_match_full():
    from repro.optim import adamw
    from repro.train.step import make_train_step
    cfg = _tiny_cfg()
    params = init_transformer(cfg, jax.random.key(0))
    opt_cfg = adamw.AdamWConfig(lr=1e-3)
    s1 = make_train_step(lambda p, t, l: loss_fn(p, t, l, cfg), opt_cfg, 1)
    s4 = make_train_step(lambda p, t, l: loss_fn(p, t, l, cfg), opt_cfg, 4)
    x, y = next(lm_batches(cfg.vocab, 8, 16, seed=1))
    opt = adamw.init(params, opt_cfg)
    p1, _, m1 = s1(params, opt, jnp.asarray(x), jnp.asarray(y))
    p4, _, m4 = s4(params, opt, jnp.asarray(x), jnp.asarray(y))
    np.testing.assert_allclose(float(m1["loss"]), float(m4["loss"]), rtol=1e-5)
    for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p4)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=2e-4, atol=2e-5)


@pytest.mark.parametrize("arch,coords", [("graphsage", False), ("egnn", True),
                                         ("dimenet", True), ("graphcast", False)])
def test_gnn_forward_and_grad(arch, coords):
    cfg = GNNConfig(arch=arch, n_layers=2, d_hidden=32, d_in=16, n_classes=5)
    g = jax.tree.map(jnp.asarray,
                     graph_batch(40, 120, 16, 5, seed=1, with_coords=coords))
    params = init_gnn(cfg, jax.random.key(0))
    out = gnn_forward(params, g, cfg)
    assert out.shape == (40, 5)
    assert bool(jnp.all(jnp.isfinite(out)))
    grads = jax.grad(gnn_loss)(params, g, cfg)
    assert all(bool(jnp.all(jnp.isfinite(x))) for x in jax.tree.leaves(grads))


def test_egnn_translation_invariance():
    """E(n) property: logits invariant under coordinate translation."""
    cfg = GNNConfig(arch="egnn", n_layers=2, d_hidden=16, d_in=8, n_classes=3)
    g = jax.tree.map(jnp.asarray,
                     graph_batch(20, 60, 8, 3, seed=2, with_coords=True))
    params = init_gnn(cfg, jax.random.key(0))
    out1 = gnn_forward(params, g, cfg)
    g2 = g._replace(coords=g.coords + jnp.array([5.0, -3.0, 11.0]))
    out2 = gnn_forward(params, g2, cfg)
    np.testing.assert_allclose(np.asarray(out1), np.asarray(out2),
                               rtol=1e-4, atol=1e-4)


def test_sasrec_train_and_serve():
    cfg = SASRecConfig(n_items=500, embed_dim=32, n_blocks=2, seq_len=12)
    params = init_sasrec(cfg, jax.random.key(0))
    x, pos, neg = next(sasrec_batches(500, 4, 12, seed=0))
    l = train_loss(params, jnp.asarray(x), jnp.asarray(pos),
                   jnp.asarray(neg), cfg)
    assert np.isfinite(float(l))
    scores = score_candidates(params, jnp.asarray(x), jnp.arange(100), cfg)
    assert scores.shape == (4, 100)
    vals, idx = serve_topk(params, jnp.asarray(x), jnp.arange(100), cfg, k=5)
    assert idx.shape == (4, 5)
    assert bool(jnp.all(vals[:, :-1] >= vals[:, 1:]))  # sorted descending


def test_sasrec_padding_is_inert():
    """Padding id 0 must not leak into representations."""
    cfg = SASRecConfig(n_items=100, embed_dim=16, n_blocks=1, seq_len=8)
    params = init_sasrec(cfg, jax.random.key(0))
    seq = jnp.array([[0, 0, 5, 7, 9, 11, 13, 17]])
    seq2 = jnp.array([[0, 0, 5, 7, 9, 11, 13, 17]])
    s1 = score_candidates(params, seq, jnp.arange(50), cfg)
    s2 = score_candidates(params, seq2, jnp.arange(50), cfg)
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s2))
