"""End-to-end behaviour tests for the paper's system (integration level)."""
import jax.numpy as jnp
import numpy as np

from repro.core.reference import MoSSo
from repro.graph.streams import (copying_model_edges,
                                 edges_to_fully_dynamic_stream,
                                 edges_to_insertion_stream)

from conftest import ground_truth_edges


def test_anytime_query_during_stream(small_fd_stream):
    """'Any time' property: neighborhood queries are correct at EVERY
    prefix of the stream, straight from the summary (Lemma 1)."""
    algo = MoSSo(seed=0, c=15, escape=0.2)
    check_at = set(range(0, len(small_fd_stream), 37))
    live = set()
    for t, (u, v, ins) in enumerate(small_fd_stream):
        algo.process(u, v, ins)
        e = (min(u, v), max(u, v))
        live.add(e) if ins else live.discard(e)
        if t in check_at:
            for q in list(algo.s.n2s)[:10]:
                expect = {w for (a, b) in live for w in (a, b)
                          if q in (a, b)} - {q}
                assert algo.s.neighbors(q) == expect


def test_compression_improves_with_structure():
    """C5 (Fig 7a): higher copying probability -> better compression."""
    ratios = []
    for beta in (0.2, 0.9):
        edges = copying_model_edges(400, 5, beta, seed=5)
        algo = MoSSo(seed=1, c=30, escape=0.2)
        algo.run(edges_to_insertion_stream(edges, seed=1))
        ratios.append(algo.s.compression_ratio())
    assert ratios[1] < ratios[0], ratios


def test_representation_memory_sublinear_vs_edges():
    """Thm. 4 flavor: |V|+phi stays below |V|+|E| (the raw graph)."""
    edges = copying_model_edges(500, 6, 0.85, seed=6)
    algo = MoSSo(seed=2, c=30, escape=0.2)
    algo.run(edges_to_insertion_stream(edges, seed=2))
    raw = len(algo.s.n2s) + algo.s.num_edges
    assert algo.s.representation_size() < raw


def test_serve_cli_end_to_end():
    from repro.launch.serve import serve
    out = serve("minicpm3-4b", batch=2, prompt_len=4, gen_tokens=6)
    assert out["tokens"].shape == (2, 6)


def test_quickstart_example_runs():
    import importlib.util
    import pathlib
    p = pathlib.Path(__file__).parent.parent / "examples" / "quickstart.py"
    spec = importlib.util.spec_from_file_location("quickstart", p)
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)   # runs main() guard-free body
