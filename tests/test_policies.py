"""Policy-layer tests (PR 8): the pluggable proposal/objective/commit triple.

Host property tests pin the refactor's two load-bearing reductions:

* uniform weights (``weight_levels <= 1``) make the weighted objective
  reproduce the exact delta-phi BIT-FOR-BIT, on the host reference and on
  the engine (common state leaves bitwise identical), and
* under BOTH objectives the live ``phi`` agrees with the independently
  refolded ``phi_recomputed()`` and with the materialized
  :class:`SummaryOutput` (``phi`` exact / ``phi_weighted`` weighted) after
  every change.

Plus registry/config pins so the policy names in ``engine/state.py``, the
implementations in ``engine/policies.py``, and the CLI choices cannot
drift apart.
"""
import random

import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:      # container has no hypothesis; deterministic shim
    from repro.testing.proptest import given, settings, strategies as st

from repro.core.engine import BatchedSummarizer, EngineConfig
from repro.core.engine import policies
from repro.core.engine import state as engine_state
from repro.core.reference import (ALGORITHMS, DynamicSummary, MoSSoMags,
                                  WeightedDynamicSummary, host_node_weight)
from repro.graph.streams import edges_to_fully_dynamic_stream, sbm_edges

from conftest import ground_truth_edges


def _cfg(**kw):
    base = dict(n_cap=256, m_cap=2048, d_cap=48, sn_cap=32, c=8, batch=16,
                escape=0.3)
    base.update(kw)
    return EngineConfig(**base)


# --------------------------------------------------------------- registries
def test_policy_registries_match_state_tuples():
    """The name tuples in state.py (the config/CLI vocabulary) and the
    implementation dicts in policies.py are the same sets, in the same
    order — a rename in one place must fail here, not at dispatch time."""
    assert tuple(policies.PROPOSALS) == engine_state.PROPOSALS
    assert tuple(policies.OBJECTIVES) == engine_state.OBJECTIVES
    assert tuple(policies.COMMIT_RULES) == engine_state.COMMIT_RULES
    for d in (policies.PROPOSALS, policies.OBJECTIVES, policies.COMMIT_RULES):
        assert all(callable(f) for f in d.values())


def test_engine_config_rejects_unknown_policies():
    with pytest.raises(ValueError):
        _cfg(proposal="random-walk")
    with pytest.raises(ValueError):
        _cfg(objective="l2")
    with pytest.raises(ValueError):
        _cfg(commit="always")


def test_engine_config_policy_triple_is_hashable_cache_key():
    """Compile caches key on the config, so distinct triples must hash as
    distinct configs and equal triples as equal configs."""
    a = _cfg(proposal="minhash", objective="exact")
    b = _cfg(proposal="minhash", objective="exact")
    c = _cfg(proposal="magsdm", objective="weighted", weight_levels=3)
    assert a == b and hash(a) == hash(b)
    assert a != c
    assert len({a, b, c}) == 2


def test_weab_cap_is_dummy_under_exact_objective():
    assert _cfg(objective="exact").table_caps()["weab"] == 8
    w = _cfg(objective="weighted")
    assert w.table_caps()["weab"] == w.table_caps()["eab"]


def test_mags_reference_registered():
    assert ALGORITHMS["mags"] is MoSSoMags


# ---------------------------------------------------- host reference: uniform
def _random_moves(s, rng, k=4):
    """Attempt k random moves via delta_phi/move; return the picks made so a
    twin summary can replay the identical sequence."""
    picks = []
    nodes = sorted(s.n2s)
    sids = sorted(s.members)
    for _ in range(k):
        if not nodes or not sids:
            break
        y = rng.choice(nodes)
        t = rng.choice(sids)
        picks.append((y, t))
    return picks


def _apply_picks(s, picks):
    """Replay (y, target) picks: compute delta_phi, move iff it saves, and
    hand back the deltas for bit-for-bit comparison."""
    out = []
    for (y, t) in picks:
        if t == s.n2s[y] or t not in s.members:
            out.append(None)
            continue
        d = s.delta_phi(y, t)
        out.append(d)
        if d <= 0:
            before = s.phi
            s.move(y, t)
            assert s.phi == before + d, "delta_phi disagrees with move()"
    return out


@settings(max_examples=6, deadline=None)
@given(st.integers(0, 9999))
def test_uniform_weights_reproduce_exact_delta_phi_bitwise(seed):
    """Property: with weight_levels=1 every weighted hook collapses to the
    base class, so WeightedDynamicSummary tracks DynamicSummary bit-for-bit
    — phi after every change, every delta_phi, every post-move state."""
    rng1, rng2 = random.Random(seed), random.Random(seed)
    edges = sbm_edges(24, 3, 0.5, 0.06, seed=seed)
    stream = edges_to_fully_dynamic_stream(edges, delete_prob=0.2,
                                           seed=seed + 1)
    ref = DynamicSummary()
    wref = WeightedDynamicSummary(weight_levels=1)
    for i, (u, v, ins) in enumerate(stream):
        (ref.insert if ins else ref.delete)(u, v)
        (wref.insert if ins else wref.delete)(u, v)
        assert wref.phi == ref.phi, f"t={i}"
        if i % 7 == 0:
            picks = _random_moves(ref, rng1)
            assert picks == _random_moves(wref, rng2)
            assert _apply_picks(ref, picks) == _apply_picks(wref, picks), \
                f"delta_phi diverged at t={i}"
            assert wref.n2s == ref.n2s and wref.P == ref.P, f"t={i}"
            assert wref.cplus == ref.cplus and wref.cminus == ref.cminus
    assert wref.phi == ref.phi == ref.phi_recomputed() == \
        wref.phi_recomputed()
    assert wref.materialize().decode_edges() == \
        ref.materialize().decode_edges() == ground_truth_edges(stream)


# --------------------------------------------------- host reference: weighted
@settings(max_examples=4, deadline=None)
@given(st.integers(0, 9999), st.integers(2, 5))
def test_weighted_reference_invariants_and_lossless_decode(seed, levels):
    """Property: under hashed node weights the live phi equals the
    materialized ``phi_weighted`` and the refolded ``phi_recomputed`` after
    every change, delta_phi predicts move() exactly, and decode stays
    lossless — weights shift encoding choices, never the edge set."""
    rng = random.Random(seed)
    edges = sbm_edges(24, 3, 0.5, 0.06, seed=seed)
    stream = edges_to_fully_dynamic_stream(edges, delete_prob=0.2,
                                           seed=seed + 1)
    s = WeightedDynamicSummary(weight_levels=levels)
    live = set()
    for i, (u, v, ins) in enumerate(stream):
        if ins:
            s.insert(u, v)
            live.add((min(u, v), max(u, v)))
        else:
            s.delete(u, v)
            live.discard((min(u, v), max(u, v)))
        if i % 7 == 0:
            _apply_picks(s, _random_moves(s, rng))  # asserts phi == phi + d
        mat = s.materialize()
        assert s.phi == mat.phi_weighted(s._w) == s.phi_recomputed(), f"t={i}"
        assert mat.decode_edges() == live, f"t={i}"
    assert live == ground_truth_edges(stream)
    # the exact phi of the same representation is a DIFFERENT number once
    # any pair weight exceeds 1 — guard against the weighted hooks silently
    # degenerating to counts
    if any(w > 1 for w in map(s._w, s.n2s)) and (s.cplus or s.cminus):
        assert mat.phi != s.phi or all(
            s._w(u) * s._w(v) == 1
            for c in (mat.c_plus, mat.c_minus) for (u, v) in c)


def test_mags_reference_end_to_end_lossless():
    """MoSSoMags (the magsdm host reference) summarizes an FD stream
    losslessly and satisfies the phi invariant."""
    edges = sbm_edges(32, 4, 0.55, 0.05, seed=9)
    stream = edges_to_fully_dynamic_stream(edges, delete_prob=0.2, seed=10)
    algo = MoSSoMags(seed=0, c=24)
    algo.run(stream)
    s = algo.s
    mat = s.materialize()
    assert s.phi == mat.phi == s.phi_recomputed()
    assert mat.decode_edges() == ground_truth_edges(stream)
    assert algo.stats.accepted > 0     # the modal-candidate scheme found moves


# ----------------------------------------------------------------- engine
def _run_stream(cfg, stream, **kw):
    return BatchedSummarizer(cfg, **kw).run(stream)


def test_engine_uniform_weighted_bitwise_equals_exact():
    """weight_levels=0 is the uniform reduction ON DEVICE too: every state
    leaf shared between the exact and weighted engines is bitwise identical
    after the same stream (weab/wsum/wsq are the weighted view's own)."""
    import jax
    import numpy as np

    edges = sbm_edges(30, 3, 0.5, 0.06, seed=17)
    stream = edges_to_fully_dynamic_stream(edges, delete_prob=0.2, seed=18)
    be = _run_stream(_cfg(objective="exact"), stream)
    bw = _run_stream(_cfg(objective="weighted", weight_levels=0), stream)
    assert be.phi == bw.phi
    skip = {"wsum", "wsq", "weab"}
    for name in type(be.state)._fields:
        if name in skip:
            continue
        le, lw = getattr(be.state, name), getattr(bw.state, name)
        for a, b in zip(jax.tree.leaves(le), jax.tree.leaves(lw)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b),
                                          err_msg=f"leaf {name}")


def test_engine_threshold_margin_zero_equals_saving():
    """commit="threshold" with margin 0 is definitionally Move-if-Saved:
    the two commit rules must produce bitwise-identical runs."""
    import jax
    import numpy as np

    edges = sbm_edges(30, 3, 0.5, 0.06, seed=19)
    stream = edges_to_fully_dynamic_stream(edges, delete_prob=0.2, seed=20)
    bs = _run_stream(_cfg(commit="saving"), stream)
    bt = _run_stream(_cfg(commit="threshold", commit_margin=0), stream)
    assert bs.phi == bt.phi
    for a, b in zip(jax.tree.leaves(bs.state), jax.tree.leaves(bt.state)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


@pytest.mark.parametrize("objective,levels", [("exact", 0), ("weighted", 3)])
def test_engine_ratio_and_phi_recomputed_vs_materialized(objective, levels):
    """compression_ratio and phi_recomputed agree with the materialized
    SummaryOutput under both objectives: phi == mat.phi (exact) ==
    mat.phi_weighted(w) (weighted; w hashes DENSE interned ids, the
    engine's weight domain) == the refolded pair table."""
    edges = sbm_edges(36, 4, 0.55, 0.05, seed=23)
    stream = edges_to_fully_dynamic_stream(edges, delete_prob=0.2, seed=24)
    cfg = _cfg(objective=objective, weight_levels=levels)
    bs = _run_stream(cfg, stream)
    mat = bs.materialize()     # asserts eab vs live edges (+ weab drift)
    if objective == "exact":
        assert bs.phi == mat.phi
    else:
        assert bs.phi == mat.phi_weighted(
            lambda d: host_node_weight(d, levels))
        if levels > 1:
            assert bs.phi != mat.phi or not (mat.c_plus or mat.c_minus)
    assert bs.phi == bs.phi_recomputed()
    assert bs.compression_ratio() == bs.phi / max(bs.num_edges, 1)
    # decode is lossless regardless of objective
    truth = ground_truth_edges(stream)
    assert {(min(bs._rev[a], bs._rev[b]), max(bs._rev[a], bs._rev[b]))
            for (a, b) in mat.decode_edges()} == truth
