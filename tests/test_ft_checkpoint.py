"""Fault tolerance: checkpoint roundtrip, elastic reshard plan, stragglers,
crash-retry loop, stream/sampler substrate."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import checkpointer
from repro.ft.resilience import (StragglerDetector, plan_elastic_mesh,
                                 rebalance_batch, run_with_retries)


def test_checkpoint_roundtrip(tmp_path):
    tree = {"a": jnp.arange(12.0).reshape(3, 4),
            "b": {"c": jnp.ones((2,), jnp.int32)}}
    p = checkpointer.save(str(tmp_path), 7, tree, extra={"cursor": 123})
    assert os.path.isdir(p)
    assert checkpointer.latest_step(str(tmp_path)) == 7
    like = jax.tree.map(lambda x: jnp.zeros_like(x), tree)
    back = checkpointer.restore(str(tmp_path), 7, like)
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(back)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert checkpointer.load_meta(str(tmp_path), 7)["extra"]["cursor"] == 123


def test_checkpoint_atomicity(tmp_path):
    tree = {"w": jnp.ones((4,))}
    checkpointer.save(str(tmp_path), 1, tree)
    checkpointer.save(str(tmp_path), 2, tree)
    # a stale tmp dir from a crashed writer must be ignored + not corrupt
    os.makedirs(str(tmp_path / "step_00000003.tmp"), exist_ok=True)
    assert checkpointer.latest_step(str(tmp_path)) == 2


def test_flatten_keys_collision_proof(tmp_path):
    # a literal '/' in a dict key must not alias a nesting boundary: both
    # trees roundtrip to their own (distinct) leaf values
    t1 = {"a/b": jnp.full((2,), 1.0)}
    t2 = {"a": {"b": jnp.full((2,), 2.0)}}
    checkpointer.save(str(tmp_path / "d1"), 0, t1)
    checkpointer.save(str(tmp_path / "d2"), 0, t2)
    b1 = checkpointer.restore(str(tmp_path / "d1"), 0, t1)
    b2 = checkpointer.restore(str(tmp_path / "d2"), 0, t2)
    np.testing.assert_array_equal(np.asarray(b1["a/b"]), 1.0)
    np.testing.assert_array_equal(np.asarray(b2["a"]["b"]), 2.0)


def test_checksums_recorded_and_verified(tmp_path):
    d = str(tmp_path)
    tree = {"w": jnp.arange(64.0)}
    checkpointer.save(d, 3, tree, blobs={"host.pkl": b"payload"})
    meta = checkpointer.load_meta(d, 3)
    assert set(meta["checksums"]) == {"arrays.npz", "host.pkl"}
    assert checkpointer.verify(d, 3)
    assert checkpointer.load_blob(d, 3, "host.pkl") == b"payload"
    # flip bytes in the payload: verify() must catch what np.load cannot
    path = tmp_path / "step_00000003" / "arrays.npz"
    data = bytearray(path.read_bytes())
    data[len(data) // 2] ^= 0xFF
    path.write_bytes(bytes(data))
    assert not checkpointer.verify(d, 3)


def test_latest_valid_step_skips_corrupt_newest(tmp_path):
    d = str(tmp_path)
    tree = {"w": jnp.ones((8,))}
    checkpointer.save(d, 1, tree)
    checkpointer.save(d, 2, tree)
    (tmp_path / "step_00000002" / "meta.json").write_text("{not json")
    assert checkpointer.latest_step(d) == 2          # present...
    assert checkpointer.latest_valid_step(d) == 1    # ...but not trusted
    assert checkpointer.valid_steps(d) == [1]


def test_straggler_detector():
    det = StragglerDetector(min_samples=4)
    for t in range(10):
        for h in ("h0", "h1", "h2", "h3"):
            det.record(h, 1.0 + 0.01 * t)
        det.record("h_slow", 3.0 + 0.01 * t)
    assert det.stragglers() == ["h_slow"]


def test_elastic_mesh_plan():
    assert plan_elastic_mesh(512, 16) == (32, 16)
    assert plan_elastic_mesh(511, 16) == (31, 16)   # lost a chip -> shrink DP
    assert plan_elastic_mesh(15, 16) is None
    assert rebalance_batch(256, 31) == [9] * 8 + [8] * 23


def test_run_with_retries_recovers(tmp_path):
    state = {"i": 0, "fails": 0}
    saved = {"step": 0}

    def step(i):
        if i == 5 and state["fails"] < 2:
            state["fails"] += 1
            raise RuntimeError("simulated node failure")
        state["i"] = i

    def save_fn(i):
        saved["step"] = i

    def restore_fn():
        return saved["step"]

    done = run_with_retries(step, save_fn, restore_fn, n_steps=10,
                            ckpt_every=2, max_failures=5)
    assert done == 10 and state["fails"] == 2


def test_train_restart_resumes(tmp_path):
    """End-to-end: train 6 steps, 'crash', resume from ckpt, finish."""
    from repro.launch.train import train
    d = str(tmp_path / "ck")
    out1 = train("graphsage-reddit", steps=6, ckpt_dir=d, ckpt_every=3,
                 log_every=0)
    assert checkpointer.latest_step(d) == 6
    out2 = train("graphsage-reddit", steps=9, ckpt_dir=d, ckpt_every=3,
                 log_every=0)
    assert len(out2["losses"]) == 3  # resumed at 6, ran 3 more


def test_stream_soundness_and_generators():
    from repro.graph.streams import (barabasi_albert_edges,
                                     copying_model_edges,
                                     edges_to_fully_dynamic_stream,
                                     edges_to_insertion_stream,
                                     validate_stream)
    edges = barabasi_albert_edges(200, 3, seed=1)
    assert validate_stream(edges_to_insertion_stream(edges, seed=2))
    fd = edges_to_fully_dynamic_stream(edges, delete_prob=0.3, seed=3)
    assert validate_stream(fd)
    assert sum(1 for (_, _, i) in fd if not i) > 0
    ce = copying_model_edges(300, 4, 0.9, seed=4)
    assert len(ce) > 300
    assert all(u < v for (u, v) in ce)


def test_fanout_sampler():
    from repro.graph.sampling import CSRGraph, sample_fanout, pad_subgraph
    rng = np.random.default_rng(0)
    senders = rng.integers(0, 100, 600).astype(np.int32)
    receivers = rng.integers(0, 100, 600).astype(np.int32)
    g = CSRGraph(100, senders, receivers)
    seeds = np.array([1, 2, 3], np.int32)
    nodes, s, r = sample_fanout(g, seeds, [5, 3], rng)
    assert list(nodes[:3]) == [1, 2, 3]
    assert len(s) == len(r)
    assert s.max(initial=0) < len(nodes) and r.max(initial=0) < len(nodes)
    # receivers of hop-1 edges are the seeds
    n_p, s_p, r_p, nm, em = pad_subgraph(nodes, s, r, 64, 128)
    assert nm.sum() == len(nodes) and em.sum() == len(s)
