"""Documentation integrity: relative markdown links must resolve.

The same check runs as a dedicated CI job (tools/check_md_links.py); having
it in tier-1 means a doc rename can't land with dangling links even when CI
is skipped locally.
"""
import sys
from pathlib import Path

ROOT = Path(__file__).resolve().parent.parent
sys.path.insert(0, str(ROOT / "tools"))

import check_md_links  # noqa: E402


def test_markdown_relative_links_resolve():
    errors = check_md_links.check(ROOT)
    assert not errors, "\n".join(errors)


def test_core_docs_exist():
    for rel in ("README.md", "docs/ARCHITECTURE.md", "docs/KNOWN_ISSUES.md",
                "src/repro/dist/README.md"):
        assert (ROOT / rel).is_file(), rel
