"""Unit + property tests for the lossless-summary state machine (Tier A)."""
import random

import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:      # container has no hypothesis; deterministic shim
    from repro.testing.proptest import given, settings, strategies as st

from repro.core.reference.dynamic_summary import DynamicSummary
from repro.core.summary import encoding_cost, is_superedge, pair_key, t_count

from conftest import ground_truth_edges


def test_encoding_cost_matches_rule():
    # the optimal rule (Sect. 3.1) and the closed-form min agree everywhere
    for t in range(0, 40):
        for e in range(0, t + 1):
            c_plus_mode = e
            super_mode = 1 + t - e
            assert encoding_cost(e, t) == (0 if e == 0 else
                                           min(c_plus_mode, super_mode))
            if e > 0:
                assert is_superedge(e, t) == (super_mode < c_plus_mode)


def _check_all(s: DynamicSummary, truth, tag=""):
    mat = s.materialize()
    assert s.phi == s.phi_recomputed(), tag
    assert s.phi == mat.phi, tag
    assert mat.decode_edges() == truth, tag
    for u in s.n2s:
        expect = {v for (a, b) in truth for v in (a, b) if u in (a, b)} - {u}
        assert s.neighbors(u) == expect, tag
        assert s.deg[u] == len(expect), tag


def _random_ops(seed: int, n_nodes: int, n_steps: int):
    rng = random.Random(seed)
    s = DynamicSummary()
    truth = set()
    for step in range(n_steps):
        op = rng.random()
        if op < 0.45 or not truth:
            u, v = rng.sample(range(n_nodes), 2)
            e = (min(u, v), max(u, v))
            if e in truth:
                continue
            truth.add(e)
            s.insert(*e)
        elif op < 0.65:
            e = rng.choice(sorted(truth))
            truth.remove(e)
            s.delete(*e)
        else:
            present = [n for n in range(n_nodes) if n in s.n2s]
            if not present:
                continue
            y = rng.choice(present)
            t = s.new_sid() if rng.random() < 0.3 else rng.choice(list(s.members))
            d = s.delta_phi(y, t)
            phi0 = s.phi
            s.move(y, t)
            assert s.phi - phi0 == d, "closed-form delta_phi != applied delta"
        _check_all(s, truth, f"seed={seed} step={step}")


@pytest.mark.parametrize("seed", range(8))
def test_fuzz_insert_delete_move(seed):
    """Losslessness + phi consistency + Lemma-1 retrieval under random ops."""
    _random_ops(seed, n_nodes=9, n_steps=50)


@settings(max_examples=25, deadline=None)
@given(st.lists(st.tuples(st.integers(0, 7), st.integers(0, 7)), max_size=40),
       st.randoms(use_true_random=False))
def test_property_lossless_stream(pairs, rnd):
    """Hypothesis: any sound stream + arbitrary moves stays lossless."""
    s = DynamicSummary()
    truth = set()
    for (u, v) in pairs:
        if u == v:
            continue
        e = (min(u, v), max(u, v))
        if e in truth:
            truth.remove(e)
            s.delete(*e)
        else:
            truth.add(e)
            s.insert(*e)
        if s.n2s and rnd.random() < 0.5:
            y = rnd.choice(sorted(s.n2s))
            tgt = rnd.choice(sorted(s.members))
            s.move(y, tgt)
    assert s.materialize().decode_edges() == truth
    assert s.phi == s.materialize().phi == s.phi_recomputed()


def test_move_to_fresh_singleton_roundtrip():
    s = DynamicSummary()
    s.insert(0, 1)
    s.insert(1, 2)
    s.insert(0, 2)
    sid0 = s.n2s[0]
    phi0 = s.phi
    fresh = s.new_sid()
    s.move(0, fresh)
    s.move(0, sid0)
    assert s.phi == phi0
    assert s.materialize().decode_edges() == {(0, 1), (0, 2), (1, 2)}


def test_phi_upper_bound_is_edge_count():
    """|P|+|C+|+|C-| <= |E| always holds under the optimal encoding."""
    rng = random.Random(3)
    s = DynamicSummary()
    edges = set()
    for _ in range(120):
        u, v = rng.sample(range(15), 2)
        e = (min(u, v), max(u, v))
        if e not in edges:
            edges.add(e)
            s.insert(*e)
    assert s.phi <= s.num_edges


def _random_state(rng: random.Random, n_nodes: int, n_steps: int,
                  ) -> DynamicSummary:
    """A randomized DynamicSummary built from sound inserts/deletes/moves."""
    s = DynamicSummary()
    live = set()
    for _ in range(n_steps):
        op = rng.random()
        if op < 0.55 or not live:
            u, v = rng.sample(range(n_nodes), 2)
            e = (min(u, v), max(u, v))
            if e not in live:
                live.add(e)
                s.insert(*e)
        elif op < 0.75:
            e = rng.choice(sorted(live))
            live.remove(e)
            s.delete(*e)
        elif s.n2s:
            y = rng.choice(sorted(s.n2s))
            t = s.new_sid() if rng.random() < 0.3 else rng.choice(sorted(s.members))
            s.move(y, t)
    return s


@pytest.mark.parametrize("seed", range(10))
def test_delta_phi_faithful_on_random_states(seed):
    """The docstring claim of dynamic_summary.py: delta_phi(y, target) equals
    the measured phi difference of actually applying move(y, target) —
    checked on randomized states, for existing, fresh, and own-sid targets,
    with and without a precomputed neighbor histogram."""
    rng = random.Random(1000 + seed)
    s = _random_state(rng, n_nodes=10, n_steps=40)
    if not s.n2s:
        return
    for trial in range(12):
        y = rng.choice(sorted(s.n2s))
        r = rng.random()
        if r < 0.25:
            target = s.new_sid()             # escape to a fresh singleton
        elif r < 0.35:
            target = s.n2s[y]                # no-op move
        else:
            target = rng.choice(sorted(s.members))
        d = s.delta_phi(y, target)
        d_hist = s.delta_phi(y, target, h=s.neighbor_hist(y))
        assert d == d_hist, "histogram-reusing path diverged"
        phi0 = s.phi
        s.move(y, target)
        assert s.phi - phi0 == d, (
            f"seed={seed} trial={trial}: closed-form {d} != "
            f"applied {s.phi - phi0}")
        assert s.phi == s.phi_recomputed()


def test_t_count():
    assert t_count(3, 4, False) == 12
    assert t_count(4, 4, True) == 6
    assert pair_key(5, 2) == (2, 5)
