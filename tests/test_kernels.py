"""Per-kernel shape/dtype sweeps: Pallas (interpret=True) vs ref.py oracle."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref


@pytest.mark.parametrize("n,e,f", [(64, 256, 32), (130, 1000, 70),
                                   (300, 2000, 128), (17, 50, 8)])
@pytest.mark.parametrize("reduce", ["sum", "min", "max"])
def test_segment_reduce_sweep(n, e, f, reduce):
    rng = np.random.default_rng(n + e)
    senders = jnp.array(rng.integers(0, n, e), jnp.int32)
    receivers = jnp.array(rng.integers(0, n, e), jnp.int32)
    x = jnp.array(rng.normal(size=(n, f)), jnp.float32)
    got = ops.segment_reduce(senders, receivers, x, n, reduce,
                             use_pallas=True, interpret=True)
    want = ref.segment_reduce_ref(senders, receivers, x, n, reduce)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("dtype,rtol", [(jnp.float32, 2e-3),
                                        (jnp.bfloat16, 3e-2)])
@pytest.mark.parametrize("b,h,hkv,t,d,causal", [
    (2, 4, 2, 256, 64, True),
    (1, 8, 8, 128, 128, True),
    (2, 4, 1, 384, 64, False),   # MQA
])
def test_flash_attention_sweep(b, h, hkv, t, d, causal, dtype, rtol):
    rng = np.random.default_rng(b * t + h)
    q = jnp.array(rng.normal(size=(b, h, t, d)), dtype)
    k = jnp.array(rng.normal(size=(b, hkv, t, d)), dtype)
    v = jnp.array(rng.normal(size=(b, hkv, t, d)), dtype)
    got = ops.attention(q, k, v, causal=causal, use_pallas=True,
                        interpret=True)
    want = ref.flash_attention_ref(q.astype(jnp.float32),
                                   k.astype(jnp.float32),
                                   v.astype(jnp.float32), causal)
    np.testing.assert_allclose(got.astype(jnp.float32), want,
                               rtol=rtol, atol=rtol)


def test_chunked_attention_matches_unchunked():
    rng = np.random.default_rng(0)
    q = jnp.array(rng.normal(size=(1, 2, 4096, 32)), jnp.float32)
    k = jnp.array(rng.normal(size=(1, 2, 4096, 32)), jnp.float32)
    v = jnp.array(rng.normal(size=(1, 2, 4096, 32)), jnp.float32)
    chunked = ref.flash_attention_ref(q, k, v, causal=True, q_chunk=512)
    full = ref.flash_attention_ref(q, k, v, causal=True, q_chunk=1 << 20)
    np.testing.assert_allclose(chunked, full, rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("rows,dim,bags", [(200, 16, 32), (1000, 64, 100)])
@pytest.mark.parametrize("mode", ["sum", "mean"])
def test_embedding_bag_sweep(rows, dim, bags, mode):
    rng = np.random.default_rng(rows)
    table = jnp.array(rng.normal(size=(rows, dim)), jnp.float32)
    lens = rng.integers(1, 7, bags)
    offsets = jnp.array(np.concatenate([[0], np.cumsum(lens)]), jnp.int32)
    idx = jnp.array(rng.integers(0, rows, int(offsets[-1])), jnp.int32)
    got = ops.embedding_bag(table, idx, offsets, mode, use_pallas=True,
                            interpret=True)
    want = ref.embedding_bag_ref(table, idx, offsets, mode)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


def test_minhash_kernel():
    rng = np.random.default_rng(5)
    senders = jnp.array(rng.integers(0, 100, 600), jnp.int32)
    receivers = jnp.array(rng.integers(0, 100, 600), jnp.int32)
    got = ops.minhash_signature(senders, receivers, 100, 11,
                                use_pallas=True, interpret=True)
    want = ref.minhash_signature_ref(senders, receivers, 100, 11)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_summary_spmm_equals_dense_spmm():
    """Queryable property as compute: A@X from (G*,C) == A@X from edges."""
    from repro.core.reference import MoSSo
    from repro.graph.streams import edges_to_insertion_stream, sbm_edges
    edges = sbm_edges(40, 4, 0.7, 0.03, seed=11)
    algo = MoSSo(seed=2, c=30)
    algo.run(edges_to_insertion_stream(edges, seed=3))
    out = algo.s.materialize()
    n = max(max(e) for e in edges) + 1
    sup_ids = {sid: i for i, sid in enumerate(sorted(out.supernodes))}
    n2s = np.zeros(n, np.int32)
    for sid, mem in out.supernodes.items():
        for u in mem:
            n2s[u] = sup_ids[sid]
    ns = len(sup_ids)
    p_src, p_dst = [], []
    self_loop = np.zeros(ns, bool)
    for (a, b) in out.superedges:
        if a == b:
            self_loop[sup_ids[a]] = True
        else:
            p_src += [sup_ids[a], sup_ids[b]]
            p_dst += [sup_ids[b], sup_ids[a]]

    def dirpairs(pairs):
        s, d = [], []
        for (u, v) in pairs:
            s += [u, v]
            d += [v, u]
        return jnp.array(s, jnp.int32), jnp.array(d, jnp.int32)

    cps, cpd = dirpairs(out.c_plus)
    cms, cmd = dirpairs(out.c_minus)
    rng = np.random.default_rng(0)
    x = jnp.array(rng.normal(size=(n, 24)), jnp.float32)
    got = ops.summary_spmm(
        x, jnp.array(n2s), ns,
        jnp.array(p_src, jnp.int32), jnp.array(p_dst, jnp.int32),
        cps, cpd, cms, cmd, jnp.array(self_loop))
    es, ed = dirpairs(list(edges))
    want = ref.dense_spmm_ref(es, ed, x)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)
