"""Per-kernel shape/dtype sweeps: Pallas (interpret=True) vs ref.py oracle."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:      # container has no hypothesis; deterministic shim
    from repro.testing.proptest import given, settings, strategies as st

from repro.core.engine import hashtable as htm
from repro.kernels import ops, ref


@pytest.mark.parametrize("n,e,f", [(64, 256, 32), (130, 1000, 70),
                                   (300, 2000, 128), (17, 50, 8)])
@pytest.mark.parametrize("reduce", ["sum", "min", "max"])
def test_segment_reduce_sweep(n, e, f, reduce):
    rng = np.random.default_rng(n + e)
    senders = jnp.array(rng.integers(0, n, e), jnp.int32)
    receivers = jnp.array(rng.integers(0, n, e), jnp.int32)
    x = jnp.array(rng.normal(size=(n, f)), jnp.float32)
    got = ops.segment_reduce(senders, receivers, x, n, reduce,
                             use_pallas=True, interpret=True)
    want = ref.segment_reduce_ref(senders, receivers, x, n, reduce)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("reduce", ["min", "max"])
def test_segment_reduce_keeps_inf_inputs(reduce):
    """Regression: empty-segment masking must key on segment COUNT, not
    isfinite — a legitimate ±inf input that survives a nonempty min/max
    used to be zeroed alongside the empty segments."""
    n = 130                         # > one 128-row block: block 1 is empty
    senders = jnp.array([0, 1, 2, 3], jnp.int32)
    receivers = jnp.array([0, 0, 1, 2], jnp.int32)
    x = jnp.zeros((n, 2), jnp.float32).at[:4].set(
        jnp.array([[np.inf, -np.inf],      # -> segment 0
                   [3.0, 4.0],             # -> segment 0
                   [-np.inf, np.inf],      # -> segment 1 (alone)
                   [1.0, -1.0]],           # -> segment 2 (alone)
                  jnp.float32))
    want = np.zeros((n, 2), np.float32)
    want[0] = [3.0, -np.inf] if reduce == "min" else [np.inf, 4.0]
    want[1] = [-np.inf, np.inf]            # ±inf must survive verbatim
    want[2] = [1.0, -1.0]
    got_ref = ref.segment_reduce_ref(senders, receivers, x, n, reduce)
    got_pl = ops.segment_reduce(senders, receivers, x, n, reduce,
                                use_pallas=True, interpret=True)
    np.testing.assert_array_equal(np.asarray(got_ref), want)
    np.testing.assert_array_equal(np.asarray(got_pl), want)


# --------------------------------------------------------------------- #
# batched hash-probe kernel: bitwise differential vs the while-loop
# lowering (the contract REPRO_TRIAL_BACKEND=pallas rests on)
# --------------------------------------------------------------------- #


def _build_table(cap, n_live, n_tomb, seed, key_space=2000):
    """A table at a given load with tombstoned chains mixed in."""
    rng = np.random.default_rng(seed)
    ht = htm.ht_new(cap)
    keys = rng.integers(0, key_space, size=(n_live + n_tomb, 2))
    keys = np.unique(keys.astype(np.int32), axis=0)
    for i, (a, b) in enumerate(keys):
        ht = htm.ht_set(ht, int(a), int(b), i + 1)
    for (a, b) in keys[n_live:]:
        ht = htm.ht_delete(ht, int(a), int(b))
    return ht, keys[:n_live]


@pytest.mark.parametrize("cap,n_live,n_tomb", [
    (64, 16, 0),        # light load
    (64, 40, 12),       # heavy load + tombstoned chains
    (256, 200, 30),     # long chains near capacity
    (16, 16, 0),        # FULL table: absent probes wrap the whole chain
])
@pytest.mark.parametrize("prehashed", [False, True])
@pytest.mark.parametrize("mode", ["find", "insert"])
def test_ht_probe_kernel_bitwise_sweep(cap, n_live, n_tomb, prehashed,
                                       mode):
    """Pallas probe kernel vs the ``hashtable.py`` while-loop lowering:
    slots, found flags and values must be BITWISE equal — present keys,
    absent keys, garbage keys (the ``ok=False`` masked-call contract),
    and full-chain wrap-around probes alike."""
    ht, live = _build_table(cap, n_live, n_tomb, seed=cap + n_live)
    rng = np.random.default_rng(7 * cap + n_live)
    qs = [live[: min(24, len(live))]]                       # present keys
    qs.append(rng.integers(0, 2000, size=(16, 2)).astype(np.int32))
    # garbage keys over the full int32 range, incl. negatives — exactly
    # what masked (ok=False) callers feed the probe on untaken arms
    qs.append(rng.integers(-2**31, 2**31, size=(16, 2)).astype(np.int32))
    q = np.concatenate(qs)
    got = ops.ht_probe(ht.k1, ht.k2, ht.val, q[:, 0], q[:, 1],
                       prehashed=prehashed, mode=mode,
                       use_pallas=True, interpret=True)
    want = ref.ht_probe_ref(ht.k1, ht.k2, ht.val, q[:, 0], q[:, 1],
                            prehashed=prehashed, mode=mode)
    for g, w, name in zip(got, want, ("slot", "found", "val")):
        np.testing.assert_array_equal(np.asarray(g), np.asarray(w),
                                      err_msg=f"{name} differs")


@pytest.mark.parametrize("batch", [1, 5, 128, 300])
def test_ht_probe_kernel_batch_shapes(batch):
    """Lane-padding edge cases: batches below, at and above one block."""
    ht, live = _build_table(64, 30, 5, seed=batch)
    rng = np.random.default_rng(batch)
    q = rng.integers(0, 2000, size=(batch, 2)).astype(np.int32)
    got = ops.ht_probe(ht.k1, ht.k2, ht.val, q[:, 0], q[:, 1],
                       use_pallas=True, interpret=True)
    want = ref.ht_probe_ref(ht.k1, ht.k2, ht.val, q[:, 0], q[:, 1])
    for g, w in zip(got, want):
        assert g.shape == (batch,)
        np.testing.assert_array_equal(np.asarray(g), np.asarray(w))


def test_ht_lookup_batch_backend_equivalence():
    """The engine-facing dispatch point: ``ht_lookup_batch`` /
    ``ht_find_batch`` under ``trial_backend_scope("pallas")`` vs the
    default XLA lowering, on the same table."""
    ht, live = _build_table(128, 70, 20, seed=3)
    rng = np.random.default_rng(3)
    q = np.concatenate([live[:20],
                        rng.integers(0, 2000, size=(30, 2))]).astype(np.int32)
    q1, q2 = jnp.asarray(q[:, 0]), jnp.asarray(q[:, 1])
    lx = htm.ht_lookup_batch(ht, q1, q2, default=-7)
    fx = htm.ht_find_batch(ht, q1, q2)
    with htm.trial_backend_scope("pallas"):
        lp = htm.ht_lookup_batch(ht, q1, q2, default=-7)
        fp = htm.ht_find_batch(ht, q1, q2)
    np.testing.assert_array_equal(np.asarray(lx), np.asarray(lp))
    for a, b in zip(fx, fp):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# --------------------------------------------------------------------- #
# masked-write contract: the property the probe kernel must reproduce
# --------------------------------------------------------------------- #


@settings(max_examples=20, deadline=None)
@given(st.integers(0, 9999),
       st.lists(st.tuples(st.sampled_from(["set", "add", "addz", "del"]),
                          st.integers(0, 14), st.integers(0, 14),
                          st.integers(-2, 3), st.booleans()),
                min_size=1, max_size=40))
def test_masked_write_contract_property(seed, script):
    """Any interleaving of ``ht_set``/``ht_add``/``ht_delete`` with random
    ``ok`` masks leaves the table leaf-bitwise equal to replaying only the
    ``ok=True`` ops — a masked op is a structural no-op even when fed a
    garbage key.  This is the contract the predicated trial engine (and
    therefore the probe kernel) rests on."""
    rng = np.random.default_rng(seed)
    full = htm.ht_new(32)       # small cap + small key space: collisions,
    replay = htm.ht_new(32)     # tombstone resurrection, near-full chains

    def apply(ht, op, k1, k2, d, ok):
        if op == "set":
            return htm.ht_set(ht, k1, k2, d, ok=ok)
        if op == "add":
            return htm.ht_add(ht, k1, k2, d, ok=ok)[0]
        if op == "addz":
            return htm.ht_add(ht, k1, k2, d, remove_if_zero=True, ok=ok)[0]
        return htm.ht_delete(ht, k1, k2, ok=ok)

    for (op, k1, k2, d, ok) in script:
        if ok:
            gk1, gk2 = k1, k2
            replay = apply(replay, op, k1, k2, d, True)
        else:   # masked call: garbage key over the full int32 range
            gk1 = int(rng.integers(-2**31, 2**31))
            gk2 = int(rng.integers(-2**31, 2**31))
        full = apply(full, op, gk1, gk2, d, ok)

    for a, b, name in zip(full, replay, ("k1", "k2", "val")):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b),
                                      err_msg=f"{name} drifted")


@pytest.mark.parametrize("dtype,rtol", [(jnp.float32, 2e-3),
                                        (jnp.bfloat16, 3e-2)])
@pytest.mark.parametrize("b,h,hkv,t,d,causal", [
    (2, 4, 2, 256, 64, True),
    (1, 8, 8, 128, 128, True),
    (2, 4, 1, 384, 64, False),   # MQA
])
def test_flash_attention_sweep(b, h, hkv, t, d, causal, dtype, rtol):
    rng = np.random.default_rng(b * t + h)
    q = jnp.array(rng.normal(size=(b, h, t, d)), dtype)
    k = jnp.array(rng.normal(size=(b, hkv, t, d)), dtype)
    v = jnp.array(rng.normal(size=(b, hkv, t, d)), dtype)
    got = ops.attention(q, k, v, causal=causal, use_pallas=True,
                        interpret=True)
    want = ref.flash_attention_ref(q.astype(jnp.float32),
                                   k.astype(jnp.float32),
                                   v.astype(jnp.float32), causal)
    np.testing.assert_allclose(got.astype(jnp.float32), want,
                               rtol=rtol, atol=rtol)


def test_chunked_attention_matches_unchunked():
    rng = np.random.default_rng(0)
    q = jnp.array(rng.normal(size=(1, 2, 4096, 32)), jnp.float32)
    k = jnp.array(rng.normal(size=(1, 2, 4096, 32)), jnp.float32)
    v = jnp.array(rng.normal(size=(1, 2, 4096, 32)), jnp.float32)
    chunked = ref.flash_attention_ref(q, k, v, causal=True, q_chunk=512)
    full = ref.flash_attention_ref(q, k, v, causal=True, q_chunk=1 << 20)
    np.testing.assert_allclose(chunked, full, rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("rows,dim,bags", [(200, 16, 32), (1000, 64, 100)])
@pytest.mark.parametrize("mode", ["sum", "mean"])
def test_embedding_bag_sweep(rows, dim, bags, mode):
    rng = np.random.default_rng(rows)
    table = jnp.array(rng.normal(size=(rows, dim)), jnp.float32)
    lens = rng.integers(1, 7, bags)
    offsets = jnp.array(np.concatenate([[0], np.cumsum(lens)]), jnp.int32)
    idx = jnp.array(rng.integers(0, rows, int(offsets[-1])), jnp.int32)
    got = ops.embedding_bag(table, idx, offsets, mode, use_pallas=True,
                            interpret=True)
    want = ref.embedding_bag_ref(table, idx, offsets, mode)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


def test_minhash_kernel():
    rng = np.random.default_rng(5)
    senders = jnp.array(rng.integers(0, 100, 600), jnp.int32)
    receivers = jnp.array(rng.integers(0, 100, 600), jnp.int32)
    got = ops.minhash_signature(senders, receivers, 100, 11,
                                use_pallas=True, interpret=True)
    want = ref.minhash_signature_ref(senders, receivers, 100, 11)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_summary_spmm_equals_dense_spmm():
    """Queryable property as compute: A@X from (G*,C) == A@X from edges."""
    from repro.core.reference import MoSSo
    from repro.graph.streams import edges_to_insertion_stream, sbm_edges
    edges = sbm_edges(40, 4, 0.7, 0.03, seed=11)
    algo = MoSSo(seed=2, c=30)
    algo.run(edges_to_insertion_stream(edges, seed=3))
    out = algo.s.materialize()
    n = max(max(e) for e in edges) + 1
    sup_ids = {sid: i for i, sid in enumerate(sorted(out.supernodes))}
    n2s = np.zeros(n, np.int32)
    for sid, mem in out.supernodes.items():
        for u in mem:
            n2s[u] = sup_ids[sid]
    ns = len(sup_ids)
    p_src, p_dst = [], []
    self_loop = np.zeros(ns, bool)
    for (a, b) in out.superedges:
        if a == b:
            self_loop[sup_ids[a]] = True
        else:
            p_src += [sup_ids[a], sup_ids[b]]
            p_dst += [sup_ids[b], sup_ids[a]]

    def dirpairs(pairs):
        s, d = [], []
        for (u, v) in pairs:
            s += [u, v]
            d += [v, u]
        return jnp.array(s, jnp.int32), jnp.array(d, jnp.int32)

    cps, cpd = dirpairs(out.c_plus)
    cms, cmd = dirpairs(out.c_minus)
    rng = np.random.default_rng(0)
    x = jnp.array(rng.normal(size=(n, 24)), jnp.float32)
    got = ops.summary_spmm(
        x, jnp.array(n2s), ns,
        jnp.array(p_src, jnp.int32), jnp.array(p_dst, jnp.int32),
        cps, cpd, cms, cmd, jnp.array(self_loop))
    es, ed = dirpairs(list(edges))
    want = ref.dense_spmm_ref(es, ed, x)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)
