"""Distributed semantics via subprocesses with 8 fake host devices.

Tests spawn a fresh interpreter with
``XLA_FLAGS=--xla_force_host_platform_device_count=8`` (the main test
process must keep 1 device — DESIGN.md), and assert sharded execution
matches single-device semantics.
"""
import os
import subprocess
import sys
import textwrap
from pathlib import Path

import pytest

SRC = str(Path(__file__).resolve().parent.parent / "src")


def run_py(code: str) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = SRC
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, env=env, timeout=900)
    assert out.returncode == 0, out.stderr[-3000:]
    return out.stdout


def test_sharded_lm_train_step_matches_single_device():
    print(run_py("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.configs import REGISTRY
        from repro.dist import sharding as shd
        from repro.models import transformer as tfm
        from repro.optim import adamw
        from repro.train.step import make_train_step

        assert len(jax.devices()) == 8
        cfg = REGISTRY["internlm2-20b"].make_smoke_config()
        params = tfm.init_transformer(cfg, jax.random.key(0))
        opt_cfg = adamw.AdamWConfig(lr=1e-3)
        opt = adamw.init(params, opt_cfg)
        step = make_train_step(lambda p, t, l: tfm.loss_fn(p, t, l, cfg), opt_cfg)
        toks = jax.random.randint(jax.random.key(1), (8, 16), 0, cfg.vocab)

        # single device
        p1, o1, m1 = jax.jit(step)(params, opt, toks, toks)

        # sharded 2x4 mesh
        mesh = jax.make_mesh((2, 4), ("data", "model"))
        p_sh = shd.tree_shardings(params, shd.LM_RULES, mesh)
        o_sh = adamw.AdamWState(step=NamedSharding(mesh, P()),
                                m=shd.tree_shardings(params, shd.LM_RULES, mesh),
                                v=shd.tree_shardings(params, shd.LM_RULES, mesh))
        b_sh = NamedSharding(mesh, P("data", None))
        jt = jax.jit(step, in_shardings=(p_sh, o_sh, b_sh, b_sh),
                     out_shardings=(p_sh, o_sh, None))
        params_s = jax.device_put(params, p_sh)
        opt_s = jax.device_put(opt, o_sh)
        p2, o2, m2 = jt(params_s, opt_s, jax.device_put(toks, b_sh),
                        jax.device_put(toks, b_sh))
        np.testing.assert_allclose(float(m1["loss"]), float(m2["loss"]), rtol=1e-5)
        for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=5e-4, atol=5e-5)
        print("sharded == single-device: OK", float(m1["loss"]))
    """))


def test_distributed_mosso_phi_equals_sum_of_shards():
    print(run_py("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import NamedSharding, PartitionSpec as P
        from jax.experimental.shard_map import shard_map
        from repro.core.engine import BatchedSummarizer, EngineConfig
        from repro.core.engine.state import new_state
        from repro.core.engine.trial import step_fn
        from repro.graph.streams import sbm_edges, edges_to_insertion_stream

        n_dev = len(jax.devices()); assert n_dev == 8
        cfg = EngineConfig(n_cap=256, m_cap=2048, d_cap=32, sn_cap=24,
                           c=8, batch=8, escape=0.3)
        mesh = jax.make_mesh((n_dev,), ("d",))

        # edge-partitioned sharded summarization: route each change to the
        # shard owning hash(min endpoint); phi_total = psum of local phis.
        edges = sbm_edges(64, 4, 0.5, 0.05, seed=3)
        stream = edges_to_insertion_stream(edges, seed=4)
        shards = [[] for _ in range(n_dev)]
        for (u, v, ins) in stream:
            shards[min(u, v) % n_dev].append((u, v, ins))

        def local(st, u, v, ins):
            st0 = jax.tree.map(lambda x: x[0], st)
            st1 = step_fn(st0, u[0], v[0], ins[0], cfg)
            return (jax.tree.map(lambda x: x[None], st1),
                    jax.lax.psum(st1.phi, "d")[None])

        st1 = new_state(cfg)
        stacked = jax.tree.map(
            lambda l: jnp.broadcast_to(l[None], (n_dev,) + l.shape), st1)
        dist = jax.jit(shard_map(
            local, mesh=mesh,
            in_specs=(jax.tree.map(lambda _: P("d"), st1), P("d"), P("d"), P("d")),
            out_specs=(jax.tree.map(lambda _: P("d"), st1), P("d")),
            check_rep=False))

        b = cfg.batch
        n_steps = max(len(s) for s in shards)
        n_steps = (n_steps + b - 1) // b
        state = stacked
        phi = None
        for i in range(n_steps):
            u = np.full((n_dev, b), -1, np.int32)
            v = np.full((n_dev, b), -1, np.int32)
            ins = np.zeros((n_dev, b), bool)
            for d in range(n_dev):
                chunk = shards[d][i*b:(i+1)*b]
                for j, (a, c, s_) in enumerate(chunk):
                    u[d, j], v[d, j], ins[d, j] = a, c, s_
            state, phi = dist(state, jnp.asarray(u), jnp.asarray(v),
                              jnp.asarray(ins))
        local_phis = np.asarray(state.phi if state.phi.ndim else None)
        # psum result equals the sum of shard phis
        total = int(np.asarray(phi)[0])
        assert total == sum(int(x) for x in np.asarray(state.phi)), \
            (total, np.asarray(state.phi))
        # sharded-summarization quality: phi_total <= |E| (each shard
        # compresses its partition losslessly)
        assert 0 < total <= len(edges)
        print("distributed mosso OK: phi_total", total, "|E|", len(edges))
    """))


def test_sharded_summarizer_lossless_across_8_devices():
    print(run_py("""
        import jax, numpy as np
        from repro.core.engine import EngineConfig, ShardedSummarizer
        from repro.graph.streams import edges_to_fully_dynamic_stream, sbm_edges

        assert len(jax.devices()) == 8
        cfg = EngineConfig(n_cap=128, m_cap=1024, d_cap=32, sn_cap=24,
                           c=8, batch=8, escape=0.3)
        edges = sbm_edges(72, 6, 0.5, 0.04, seed=7)
        stream = edges_to_fully_dynamic_stream(edges, delete_prob=0.2, seed=8)
        ss = ShardedSummarizer(cfg)       # one partition per device
        assert ss.n_shards == 8
        ss.run(stream)

        truth = set()
        for (u, v, ins) in stream:
            e = (min(u, v), max(u, v))
            truth.add(e) if ins else truth.discard(e)

        out = ss.materialize()
        assert len(out.shards) == 8
        assert out.decode_edges() == truth            # lossless union decode
        assert ss.live_edges() == truth
        assert out.phi == ss.phi == sum(ss.shard_phis()) == ss.phi_recomputed()
        assert ss.num_edges == len(truth)
        assert 0 < ss.phi <= len(truth)               # per-shard compression
        loads = [int(x) for x in np.asarray(ss.state.num_edges)]
        assert sum(1 for l in loads if l > 0) >= 6, loads
        print("sharded summarizer OK: phi", ss.phi, "|E|", len(truth),
              "shard loads", loads)
    """))


def test_device_router_matches_host_routing_across_8_devices():
    """Host-vs-device routing differential on a real 8-device all_to_all,
    with n_shards=16 so each device carries two shard replicas (the router's
    lane layout is [n_dev, n_loc, lane_cap])."""
    print(run_py("""
        import jax, numpy as np
        from repro.core.engine import EngineConfig, ShardedSummarizer
        from repro.graph.streams import edges_to_fully_dynamic_stream, sbm_edges

        assert len(jax.devices()) == 8
        cfg = EngineConfig(n_cap=128, m_cap=1024, d_cap=32, sn_cap=24,
                           c=8, batch=8, escape=0.3)
        edges = sbm_edges(72, 6, 0.5, 0.04, seed=7)
        stream = edges_to_fully_dynamic_stream(edges, delete_prob=0.2, seed=8)
        kw = dict(n_shards=16, router_chunk=128)
        dev = ShardedSummarizer(cfg, routing="device", **kw)
        host = ShardedSummarizer(cfg, routing="host", **kw)
        live = set()
        for off in range(0, len(stream), 128):
            chunk = stream[off:off + 128]
            dev.process(chunk); host.process(chunk)
            for (u, v, ins) in chunk:
                e = (min(u, v), max(u, v))
                live.add(e) if ins else live.discard(e)
            assert dev.router_overflows == 0
            assert dev.shard_phis() == host.shard_phis(), off
            assert dev.materialize().decode_edges() == live, off
            assert host.materialize().decode_edges() == live, off
        assert dev.live_edges() == live
        assert 0 < dev.phi <= len(live)
        print("8-device router differential OK: phi", dev.phi,
              "|E|", len(live))
    """))


def test_device_router_drains_skew_across_8_devices():
    """Key-skewed stream (every change routed to one shard: the hub's
    62-bit hash undercuts every leaf's, so it is always the canonical-pair
    key) at a tiny lane_cap: the on-device drain loop runs many real
    all_to_all rounds and still matches host routing bit for bit — no host
    fallback, no per-chunk watermark sync."""
    print(run_py("""
        import jax, numpy as np
        from repro.core.engine import EngineConfig, ShardedSummarizer
        from repro.dist.labelhash import hash_label

        assert len(jax.devices()) == 8
        cfg = EngineConfig(n_cap=128, m_cap=1024, d_cap=32, sn_cap=24,
                           c=8, batch=8, escape=0.3)
        leaves = ["x%03d" % i for i in range(1, 100)]
        lo = min(hash_label(x) for x in leaves)
        hub = next(h for h in ("hub%d" % j for j in range(100000))
                   if hash_label(h) < lo)
        stream = [(hub, x, True) for x in leaves]
        kw = dict(n_shards=16, router_chunk=128)
        dev = ShardedSummarizer(cfg, routing="device", lane_cap=2, **kw)
        host = ShardedSummarizer(cfg, routing="host", **kw)
        assert dev.router_geometry.n_dev == 8
        assert dev.sync_free and dev.router_geometry.drain_guaranteed
        for off in range(0, len(stream), 128):
            dev.process(stream[off:off + 128])
            host.process(stream[off:off + 128])
        st = dev.stats()
        assert dev.router_overflows == 0 and st["router_syncs"] == 0
        assert st["router_drain_rounds"] >= 2, st
        assert dev.shard_phis() == host.shard_phis()
        for d, h in zip(dev.host_states(), host.host_states()):
            for name, dl, hl in zip(d._fields, d, h):
                np.testing.assert_array_equal(np.asarray(dl), np.asarray(hl),
                                              err_msg=name)
        truth = {(min(hub, x), max(hub, x)) for x in leaves}
        assert dev.live_edges() == truth
        assert dev.materialize().decode_edges() == truth
        print("8-device skew drain OK:", st["router_drain_rounds"], "rounds")
    """))


def test_vmapped_replicas_bitwise_across_8_devices():
    """PR-5 satellite: with 8 fake devices and 16 shards (two replicas
    stacked per device), the vmapped replica layout runs its batched
    engine program on a real mesh and stays leaf-bitwise identical to the
    lax.map layout and to host routing — including the intern tables."""
    print(run_py("""
        import jax, numpy as np
        from repro.core.engine import EngineConfig, ShardedSummarizer
        from repro.graph.streams import edges_to_fully_dynamic_stream, sbm_edges

        assert len(jax.devices()) == 8
        cfg = EngineConfig(n_cap=128, m_cap=1024, d_cap=32, sn_cap=24,
                           c=8, batch=8, escape=0.3)
        edges = sbm_edges(72, 6, 0.5, 0.04, seed=7)
        stream = edges_to_fully_dynamic_stream(edges, delete_prob=0.2, seed=8)
        kw = dict(n_shards=16, router_chunk=128)
        vm = ShardedSummarizer(cfg, routing="device", replica_exec="vmap", **kw)
        mp = ShardedSummarizer(cfg, routing="device", replica_exec="map", **kw)
        host = ShardedSummarizer(cfg, routing="host", replica_exec="vmap", **kw)
        assert vm.router_geometry.n_dev == 8
        assert vm.router_geometry.n_loc == 2      # vmap axis is 2 replicas
        for off in range(0, len(stream), 128):
            vm.process(stream[off:off + 128])
            mp.process(stream[off:off + 128])
            host.process(stream[off:off + 128])
        for other in (mp, host):
            assert vm.shard_phis() == other.shard_phis()
            for a, b in zip(vm.host_states(), other.host_states()):
                for name, al, bl in zip(a._fields, a, b):
                    np.testing.assert_array_equal(
                        np.asarray(al), np.asarray(bl), err_msg=name)
            for a, b in zip(vm.host_interns(), other.host_interns()):
                assert int(a.n_nodes) == int(b.n_nodes)
                np.testing.assert_array_equal(np.asarray(a.l2h),
                                              np.asarray(b.l2h))
        truth = set()
        for (u, v, ins) in stream:
            e = (min(u, v), max(u, v))
            truth.add(e) if ins else truth.discard(e)
        assert vm.live_edges() == truth
        assert vm.materialize().decode_edges() == truth
        st = vm.stats()
        assert st["router_syncs"] == 0 and st["router_host_dict_ops"] == 0
        print("8-device vmapped replicas OK: phi", vm.phi)
    """))


def test_data_parallel_wrapper_and_cache():
    print(run_py("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.dist import sharding as shd

        mesh = jax.make_mesh((8,), ("data",))
        x = jnp.arange(64.0).reshape(8, 8)

        g = shd.data_parallel(lambda a: a * 2.0 + 1.0, mesh)
        np.testing.assert_allclose(np.asarray(g(x)), np.asarray(x) * 2 + 1)
        np.testing.assert_allclose(np.asarray(g(x)), np.asarray(x) * 2 + 1)

        # distinct pytree STRUCTURES with identical leaves must not collide
        # in the compile cache (keyed on treedef + avals)
        h = shd.data_parallel(
            lambda t: t[0] + t[1] if isinstance(t, tuple) else t["a"] - t["b"],
            mesh)
        got_t = np.asarray(h((x, x)))
        got_d = np.asarray(h({"a": x, "b": x}))
        np.testing.assert_allclose(got_t, 2 * np.asarray(x))
        np.testing.assert_allclose(got_d, np.zeros_like(np.asarray(x)))

        # a leaf with FEWER dims than its rule takes the rule's TRAILING
        # entries: rank-1 'embed' gets the 'embed' (fsdp->data) entry, never
        # the leading 'vocab' one
        from jax.sharding import PartitionSpec as P
        spec = shd.spec_for_leaf("embed", (64,), mesh, shd.LM_RULES)
        assert spec == P("data"), spec
        assert shd.spec_for_leaf("embed", (), mesh, shd.LM_RULES) == P()
        print("data_parallel OK")
    """))


def test_compressed_psum_error_bounded():
    print(run_py("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.experimental.shard_map import shard_map
        from jax.sharding import PartitionSpec as P
        from repro.dist.collectives import compressed_psum, int8_quantize, int8_dequantize

        x = jnp.array(np.random.default_rng(0).normal(size=(8, 64)), jnp.float32)
        q, s = int8_quantize(x[0])
        err = float(jnp.max(jnp.abs(int8_dequantize(q, s) - x[0])))
        assert err <= float(s) * 0.51 + 1e-6

        mesh = jax.make_mesh((8,), ("d",))
        f = shard_map(lambda a: compressed_psum(a, "d"), mesh=mesh,
                      in_specs=P("d"), out_specs=P(), check_rep=False)
        got = f(x)
        want = jnp.sum(x, axis=0)
        rel = float(jnp.max(jnp.abs(got - want)) / (jnp.max(jnp.abs(want)) + 1e-9))
        assert rel < 0.02, rel
        print("compressed psum OK, rel err", rel)
    """))
