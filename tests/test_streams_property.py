"""Property-based soundness tests for fully dynamic stream generation."""
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:      # container has no hypothesis; deterministic shim
    from repro.testing.proptest import given, settings, strategies as st

from repro.graph.streams import (edges_to_fully_dynamic_stream,
                                 edges_to_insertion_stream,
                                 erdos_renyi_edges, validate_stream)


@settings(max_examples=40, deadline=None)
@given(st.integers(0, 10 ** 6), st.integers(0, 3))
def test_fd_stream_sound_across_seeds(seed, pidx):
    """Sect. 2.1 soundness: no deletion of a missing edge, no duplicate
    insertion of a live edge, for arbitrary seeds and delete probabilities."""
    delete_prob = (0.0, 0.1, 0.3, 1.0)[pidx]
    edges = erdos_renyi_edges(24, 50, seed=seed % 9973)
    stream = edges_to_fully_dynamic_stream(edges, delete_prob=delete_prob,
                                           seed=seed)
    assert validate_stream(stream)
    inserts = [(u, v) for (u, v, ins) in stream if ins]
    deletes = [(min(u, v), max(u, v)) for (u, v, ins) in stream if not ins]
    # every edge inserted exactly once, deletions are a sub-multiset-free set
    assert sorted(inserts) == sorted(edges)
    assert len(set(deletes)) == len(deletes)
    assert set(deletes) <= set(edges)
    assert len(deletes) == len(stream) - len(edges)
    if delete_prob == 0.0:
        assert not deletes
    if delete_prob == 1.0:
        assert len(deletes) == len(edges)


@settings(max_examples=25, deadline=None)
@given(st.integers(0, 10 ** 6))
def test_insertion_stream_sound_across_seeds(seed):
    edges = erdos_renyi_edges(20, 40, seed=seed % 7919)
    stream = edges_to_insertion_stream(edges, seed=seed)
    assert validate_stream(stream)
    assert all(ins for (_, _, ins) in stream)
    assert sorted((u, v) for (u, v, _) in stream) == sorted(edges)
    # same seed -> same order; shuffle=False preserves input order
    again = edges_to_insertion_stream(edges, seed=seed)
    assert again == stream
    plain = edges_to_insertion_stream(edges, seed=seed, shuffle=False)
    assert [(u, v) for (u, v, _) in plain] == list(edges)


def test_fd_deletion_rate_tracks_delete_prob():
    """Aggregate deletion frequency ~= delete_prob (law of large numbers:
    600 edges x 20 seeds, tolerance 4 sigma)."""
    p = 0.2
    edges = erdos_renyi_edges(80, 600, seed=0)
    n_del = n_tot = 0
    for seed in range(20):
        stream = edges_to_fully_dynamic_stream(edges, delete_prob=p, seed=seed)
        n_del += sum(1 for (_, _, ins) in stream if not ins)
        n_tot += len(edges)
    rate = n_del / n_tot
    sigma = (p * (1 - p) / n_tot) ** 0.5
    assert abs(rate - p) < 4 * sigma, (rate, p, sigma)
