"""Behaviour tests for the four streaming algorithms + MoSSo's devices."""
import random
from collections import Counter

import pytest

from repro.core.reference import (ALGORITHMS, DynamicSummary, MinHashClusters,
                                  MoSSo, MoSSoGreedy, MoSSoMCMC, MoSSoSimple,
                                  get_random_neighbors)
from repro.graph.streams import (copying_model_edges,
                                 edges_to_fully_dynamic_stream,
                                 edges_to_insertion_stream, sbm_edges)

from conftest import ground_truth_edges


@pytest.mark.parametrize("name", list(ALGORITHMS))
def test_all_algorithms_lossless(name, small_fd_stream):
    algo = ALGORITHMS[name](seed=3)
    if hasattr(algo, "c"):
        algo.c = 20
    algo.run(small_fd_stream)
    out = algo.s.materialize()
    assert out.decode_edges() == ground_truth_edges(small_fd_stream)
    assert algo.s.phi == out.phi == algo.s.phi_recomputed()
    assert 0 < algo.s.compression_ratio() <= 1.0 + 1e-9


def test_mosso_compresses_structured_graphs():
    """C2: on community graphs MoSSo gets well below ratio 1."""
    edges = sbm_edges(80, 4, 0.7, 0.01, seed=5)
    algo = MoSSo(seed=1, c=40, escape=0.2)
    algo.run(edges_to_insertion_stream(edges, seed=1))
    assert algo.s.compression_ratio() < 0.75


def test_mosso_beats_mcmc_on_compression():
    """C2 ordering: MoSSo < MCMC baseline in phi (paper Fig. 5)."""
    edges = sbm_edges(60, 4, 0.6, 0.02, seed=7)
    stream = edges_to_insertion_stream(edges, seed=2)
    m = MoSSo(seed=1, c=40, escape=0.2)
    m.run(stream)
    mc = MoSSoMCMC(seed=1)
    mc.run(stream)
    assert m.s.phi < mc.s.phi


def test_get_random_neighbors_uniform():
    """Thm. 1-2: Alg. 2 samples uniformly from N(u) on the representation."""
    s = DynamicSummary()
    rng = random.Random(0)
    edges = sbm_edges(30, 3, 0.7, 0.05, seed=9)
    for (u, v) in edges:
        s.insert(u, v)
    # force some superedge structure by grouping
    algo = MoSSoGreedy(seed=0)
    algo.s = s
    for u in list(s.n2s)[:10]:
        algo.trials(u)
    u = max(s.deg, key=lambda x: s.deg[x])
    true_nbrs = s.neighbors(u)
    n = 4000
    samples = get_random_neighbors(s, u, n, random.Random(1))
    counts = Counter(samples)
    assert set(counts) <= true_nbrs
    assert set(counts) == true_nbrs          # every neighbor reachable
    expect = n / len(true_nbrs)
    for w, c in counts.items():
        assert abs(c - expect) < 6 * (expect ** 0.5), (w, c, expect)


def test_minhash_jaccard_monotone():
    """Same-cluster probability grows with neighborhood similarity."""
    hits_similar = hits_dissimilar = 0
    trials = 60
    for seed in range(trials):
        s = DynamicSummary()
        base = list(range(2, 12))
        for w in base:
            s.insert(0, w)
            s.insert(1, w)       # nodes 0,1: identical neighborhoods
        s.insert(20, 21)         # nodes 20,21: disjoint from 0's
        s.insert(20, 22)
        mh = MinHashClusters(seed=seed)
        for u in (0, 1, 20):
            mh._recompute(s, u)
        hits_similar += mh.same_cluster(0, 1)
        hits_dissimilar += mh.same_cluster(0, 20)
    assert hits_similar == trials            # jaccard 1.0 -> always same
    assert hits_dissimilar <= trials * 0.2   # jaccard ~0 -> rarely same


def test_minhash_incremental_matches_recompute():
    s = DynamicSummary()
    mh = MinHashClusters(seed=4)
    rng = random.Random(0)
    live = set()
    for step in range(300):
        if rng.random() < 0.6 or not live:
            u, v = rng.sample(range(12), 2)
            e = (min(u, v), max(u, v))
            if e in live:
                continue
            live.add(e)
            s.insert(*e)
            mh.on_insert(s, *e)
        else:
            e = rng.choice(sorted(live))
            live.remove(e)
            s.delete(*e)
            mh.on_delete(s, *e)
        for u in list(s.n2s):
            expect = min((mh.hash_node(w) for w in s.neighbors(u)),
                         default=mh.minh.get(u) if not s.neighbors(u) else None)
            if s.neighbors(u):
                assert mh.cluster(u) == expect, f"step {step} node {u}"


def test_escape_enables_reorganization():
    """C1/Limitation 1: escape > 0 must not be catastrophically worse, and
    trials must actually accept moves (the mechanism is alive)."""
    edges = copying_model_edges(150, 4, 0.8, seed=3)
    stream = edges_to_insertion_stream(edges, seed=4)
    with_escape = MoSSoSimple(seed=1, escape=0.3, c=30)
    with_escape.run(stream)
    assert with_escape.stats.escapes > 0
    assert with_escape.stats.accepted > 0
