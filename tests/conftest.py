import random
import sys
from pathlib import Path

import pytest

SRC = Path(__file__).resolve().parent.parent / "src"
if str(SRC) not in sys.path:
    sys.path.insert(0, str(SRC))

# NOTE: never set xla_force_host_platform_device_count here — smoke tests and
# benches must see 1 device (DESIGN.md / dry-run contract).  Multi-device
# semantics are tested via subprocesses in tests/test_dist.py.


def ground_truth_edges(stream):
    g = set()
    for (u, v, ins) in stream:
        e = (min(u, v), max(u, v))
        if ins:
            g.add(e)
        else:
            g.discard(e)
    return g


@pytest.fixture(scope="session")
def small_fd_stream():
    from repro.graph.streams import edges_to_fully_dynamic_stream, sbm_edges
    edges = sbm_edges(48, 4, 0.6, 0.02, seed=1)
    return edges_to_fully_dynamic_stream(edges, delete_prob=0.2, seed=2)
