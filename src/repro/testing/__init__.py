"""Test-support utilities shipped with the library (no external deps)."""
from repro.testing import proptest  # noqa: F401

__all__ = ["proptest"]
