"""Minimal deterministic property-testing fallback (hypothesis API subset).

The container image does not ship ``hypothesis``; the tier-1 suite only uses
``given``/``settings`` plus a handful of strategies, so this module provides
a deterministic reimplementation of exactly that subset.  Test modules prefer
the real package and fall back here::

    try:
        from hypothesis import given, settings, strategies as st
    except ImportError:
        from repro.testing.proptest import given, settings, strategies as st

Examples are generated from per-test seeds derived with crc32 (stable across
processes and runs — ``hash()`` randomization never leaks in), so failures
reproduce exactly.  There is no shrinking: the failing example's index and
arguments are attached to the raised error instead.
"""
from __future__ import annotations

import functools
import random
import zlib
from types import SimpleNamespace
from typing import Any, Callable, List

DEFAULT_MAX_EXAMPLES = 50


class Strategy:
    """A value generator: ``draw(rng) -> value``."""

    def __init__(self, draw: Callable[[random.Random], Any], label: str = ""):
        self._draw = draw
        self.label = label

    def draw(self, rng: random.Random) -> Any:
        return self._draw(rng)

    def map(self, f: Callable[[Any], Any]) -> "Strategy":
        return Strategy(lambda rng: f(self._draw(rng)), f"map({self.label})")


def integers(min_value: int, max_value: int) -> Strategy:
    return Strategy(lambda rng: rng.randint(min_value, max_value),
                    f"integers({min_value},{max_value})")


def floats(min_value: float, max_value: float) -> Strategy:
    return Strategy(lambda rng: rng.uniform(min_value, max_value),
                    f"floats({min_value},{max_value})")


def booleans() -> Strategy:
    return Strategy(lambda rng: rng.random() < 0.5, "booleans")


def sampled_from(options) -> Strategy:
    opts = list(options)
    return Strategy(lambda rng: opts[rng.randrange(len(opts))], "sampled_from")


def tuples(*elems: Strategy) -> Strategy:
    return Strategy(lambda rng: tuple(e.draw(rng) for e in elems), "tuples")


def lists(elem: Strategy, min_size: int = 0, max_size: int = 25) -> Strategy:
    def draw(rng: random.Random) -> List[Any]:
        return [elem.draw(rng)
                for _ in range(rng.randint(min_size, max_size))]
    return Strategy(draw, f"lists({elem.label})")


def randoms(use_true_random: bool = False, **_kw) -> Strategy:
    """A seeded ``random.Random`` (never true-random here: determinism)."""
    return Strategy(lambda rng: random.Random(rng.getrandbits(64)), "randoms")


strategies = SimpleNamespace(
    integers=integers, floats=floats, booleans=booleans,
    sampled_from=sampled_from, tuples=tuples, lists=lists, randoms=randoms)


def settings(max_examples: int = DEFAULT_MAX_EXAMPLES, deadline=None, **_kw):
    """Attach run parameters to a ``given``-wrapped test (deadline ignored)."""
    def deco(fn):
        fn._proptest_max_examples = max_examples
        return fn
    return deco


def given(*strats: Strategy):
    """Run the test once per generated example, deterministically seeded."""
    def deco(fn):
        base_seed = zlib.crc32(
            f"{fn.__module__}.{fn.__qualname__}".encode())

        @functools.wraps(fn)
        def runner():
            n = getattr(runner, "_proptest_max_examples",
                        DEFAULT_MAX_EXAMPLES)
            for i in range(n):
                rng = random.Random((base_seed << 20) + i)
                args = tuple(s.draw(rng) for s in strats)
                try:
                    fn(*args)
                except Exception as err:  # annotate, no shrinking
                    raise AssertionError(
                        f"property falsified on example {i}/{n} "
                        f"args={args!r}") from err

        # pytest introspects the signature through __wrapped__; drop it so
        # the original's parameters are not mistaken for fixtures.
        del runner.__wrapped__
        return runner

    return deco
