"""AdamW with sharding-friendly state and low-precision moment option.

Optimizer state mirrors the parameter pytree, so ``tree_shardings`` specs
apply verbatim (FSDP shards moments with the params — the ZeRO-style memory
model that lets llama3-405b train on one v5e pod with bf16 moments).
"""
from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    moment_dtype: Any = jnp.float32   # bf16 for memory-bound giants
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_frac: float = 0.1


class AdamWState(NamedTuple):
    step: jax.Array
    m: Any
    v: Any


def init(params, cfg: AdamWConfig) -> AdamWState:
    zeros = lambda p: jnp.zeros(p.shape, cfg.moment_dtype)
    return AdamWState(step=jnp.zeros((), jnp.int32),
                      m=jax.tree.map(zeros, params),
                      v=jax.tree.map(zeros, params))


def schedule(step: jax.Array, cfg: AdamWConfig) -> jax.Array:
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    prog = jnp.clip((step - cfg.warmup_steps)
                    / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1), 0, 1)
    cos = 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return cfg.lr * warm * (cfg.min_lr_frac + (1 - cfg.min_lr_frac) * cos)


def global_norm(tree) -> jax.Array:
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def update(grads, state: AdamWState, params, cfg: AdamWConfig):
    """Returns (new_params, new_state, metrics)."""
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / (gnorm + 1e-9))
    step = state.step + 1
    lr = schedule(step, cfg)
    b1, b2 = cfg.b1, cfg.b2
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m32 = b1 * m.astype(jnp.float32) + (1 - b1) * g
        v32 = b2 * v.astype(jnp.float32) + (1 - b2) * g * g
        mhat = m32 / bc1
        vhat = v32 / bc2
        step_ = mhat / (jnp.sqrt(vhat) + cfg.eps) + cfg.weight_decay * p.astype(jnp.float32)
        newp = (p.astype(jnp.float32) - lr * step_).astype(p.dtype)
        return newp, m32.astype(cfg.moment_dtype), v32.astype(cfg.moment_dtype)

    out = jax.tree.map(upd, params, grads, state.m, state.v)
    newp = jax.tree.map(lambda t: t[0], out, is_leaf=lambda t: isinstance(t, tuple))
    newm = jax.tree.map(lambda t: t[1], out, is_leaf=lambda t: isinstance(t, tuple))
    newv = jax.tree.map(lambda t: t[2], out, is_leaf=lambda t: isinstance(t, tuple))
    return newp, AdamWState(step=step, m=newm, v=newv), {
        "grad_norm": gnorm, "lr": lr}
