"""Stable 62-bit label hashing for the sharded dispatch path (host side).

Through PR 3 every stream change paid a host tax before it ever reached the
device: ``ShardedSummarizer`` assigned each caller label a dense gid from a
Python dict (``_gid``), per change, per chunk.  The dict was the only
centralized, order-dependent step left in dispatch — the classic argument
for hash-based id assignment over sequential counters in scalable
summarization (Beg et al., arXiv:1806.03936).

This module replaces the counter with a **pure stable hash**: every label
maps to a 62-bit hash, carried on device as two non-negative ``int32``
words ``(hi, lo)`` — exactly the key shape of the engine's open-addressing
tables (:mod:`repro.core.engine.hashtable`), so shards intern the words
directly into their dense local id space with no host involvement.  The
host's only per-chunk work is one vectorized numpy pass (integer labels)
or one pure-function pass (arbitrary hashables); the reverse map needed by
``decode``/``shard_of`` is folded lazily at sync points, off the dispatch
path.

Hash functions (both fixed forever — they define placement):

* integer labels: splitmix64 finalizer over the two's-complement uint64,
  vectorized with numpy on whole chunks;
* any other hashable: blake2b-8 over a stable byte encoding (str/bytes
  verbatim with a type tag, anything else over ``repr``).

The 62-bit space makes collisions (two labels silently merged into one
node) astronomically unlikely at realistic node counts (~1e-10 at ten
million labels); the lazy reverse-map fold still *checks* and raises on a
real collision, so the failure mode is loud, never silent corruption.
"""
from __future__ import annotations

import hashlib
from typing import Sequence, Tuple

import numpy as np

MASK31 = 0x7FFFFFFF          # each on-device hash word is a 31-bit int32
MASK62 = (1 << 62) - 1
MASK64 = (1 << 64) - 1
_U64 = np.uint64


def _splitmix64(z: np.ndarray) -> np.ndarray:
    """splitmix64 finalizer, vectorized over uint64 (wraps mod 2**64)."""
    z = (z + _U64(0x9E3779B97F4A7C15))
    z = (z ^ (z >> _U64(30))) * _U64(0xBF58476D1CE4E5B9)
    z = (z ^ (z >> _U64(27))) * _U64(0x94D049BB133111EB)
    return z ^ (z >> _U64(31))


def _splitmix64_int(x: int) -> int:
    """Scalar splitmix64 in Python ints — bit-identical to the numpy path
    (which wraps mod 2**64), without numpy's scalar-overflow warnings."""
    z = (x + 0x9E3779B97F4A7C15) & MASK64
    z = ((z ^ (z >> 30)) * 0xBF58476D1CE4E5B9) & MASK64
    z = ((z ^ (z >> 27)) * 0x94D049BB133111EB) & MASK64
    return z ^ (z >> 31)


def _hash_bytes(data: bytes) -> int:
    return int.from_bytes(
        hashlib.blake2b(data, digest_size=8).digest(), "little")


def _fuse62(z: int) -> int:
    """Fold a 64-bit hash into the packed 62-bit `(hi << 31 | lo)` form."""
    return ((z >> 33) << 31 | (z & MASK31)) & MASK62


def hash_label(label: object) -> int:
    """The 62-bit hash of one label (`hi << 31 | lo`), as a Python int.

    Numeric labels that compare equal as dict keys must hash equal (the
    pre-hash gid dict keyed on label equality): bools and integral floats
    canonicalize to int before hashing.  Exotic numeric types (Decimal,
    Fraction) fall to the ``repr`` path and do NOT join that equivalence.
    """
    if isinstance(label, (bool, np.bool_)):
        # bool subclasses int for dict keys; keep that equivalence here
        label = int(label)
    elif isinstance(label, (float, np.floating)):
        f = float(label)
        if f.is_integer() and -(1 << 63) <= f < (1 << 64):
            label = int(f)       # 1.0 and 1 are one dict key -> one node
        else:
            # non-integral: own type tag (1.5 must not collide with the
            # string "1.5"), repr for stability across float widths
            return _fuse62(_hash_bytes(b"f\x00" + repr(f).encode("ascii")))
    if isinstance(label, (int, np.integer)) and -(1 << 63) <= label < (1 << 64):
        # covers the full uint64-representable range so the vectorized
        # path (int64 or uint64 arrays) can never disagree with this one
        z = _splitmix64_int(int(label) & MASK64)
    elif isinstance(label, str):
        z = _hash_bytes(b"s\x00" + label.encode("utf-8"))
    elif isinstance(label, bytes):
        z = _hash_bytes(b"b\x00" + label)
    else:
        # stable within a run; ``repr`` stability across runs is the
        # caller's contract for exotic label types
        z = _hash_bytes(b"r\x00" + repr(label).encode("utf-8"))
    return _fuse62(int(z))


def hash_words(labels: Sequence[object]) -> Tuple[np.ndarray, np.ndarray]:
    """Hash a chunk of labels into device words ``(hi, lo)``, int32 each.

    Integer labels take the vectorized numpy path — zero Python-object
    work per element; anything else falls back to :func:`hash_label` per
    element (pure function, still no dict/counter mutation).
    """
    try:
        arr = np.asarray(labels)
    except (ValueError, TypeError):   # ragged label tuples etc.
        arr = np.empty(0, object)
    # tuple labels coerce to a 2-D int array — ndim guards against that
    if arr.ndim != 1 or arr.dtype.kind not in "iub":
        comb = np.fromiter((hash_label(x) for x in labels), np.int64,
                           len(labels))
        return ((comb >> 31).astype(np.int32),
                (comb & MASK31).astype(np.int32))
    z = _splitmix64(arr.astype(np.int64).astype(_U64))
    hi = ((z >> _U64(33)) & _U64(MASK31)).astype(np.int32)
    lo = (z & _U64(MASK31)).astype(np.int32)
    return hi, lo


def combine(hi: np.ndarray, lo: np.ndarray) -> np.ndarray:
    """Fuse device words back into the 62-bit host form (int64)."""
    return (hi.astype(np.int64) << 31) | lo.astype(np.int64)
