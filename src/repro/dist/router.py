"""Device-side stream router for edge-partitioned summarization.

:class:`~repro.core.engine.api.ShardedSummarizer` partitions the edge stream
over a fleet of engine replicas by canonical-pair key
``min(h(u), h(v)) % n_shards``, where ``h`` is a stable 62-bit label hash
(:mod:`repro.dist.labelhash`).  Until PR 4 the key was computed on dense
gids a host-side Python dict assigned in encounter order — a per-change
host tax and the last centralized step in dispatch.  The router now
consumes raw hashed labels and runs the whole dispatch path on device, as
a two-stage software pipeline:

**Stage 1 — route** (:func:`make_route_step`, no state dependencies):

1. The host hands the router one flat chunk of hashed changes (four
   ``int32`` hash words + a flag per change, ``-1``-padded to a fixed
   ``chunk`` length, split contiguously over the mesh so device ``d``
   holds stream positions ``[d*n_in, (d+1)*n_in)``).
2. Each source device computes shard keys and scatters its changes into a
   capacity-bounded send buffer of ``lane_cap`` slots per (source device,
   destination shard) lane.
3. One ``lax.all_to_all`` inside the ``shard_map`` region delivers every
   lane to the device owning its destination shard; the receiver compacts
   the lanes source-major, which reconstructs global stream order.
4. If some lane overflowed, steps 2-3 repeat as a bounded on-device
   **drain loop** (``lax.while_loop``): each round routes the pending
   stream prefix up to the first still-overflowing position (agreed with
   ``lax.pmin``) and appends the deliveries to the per-shard buckets, so
   multi-round delivery is lossless and order-preserving without any host
   round-trip.

**Stage 2 — engine** (:func:`make_engine_step`, consumes stage-1 buckets):

5. Each shard interns the received hash words into its dense local id
   space (:class:`InternState`, first-come-first-served — the same order
   host bucketing would produce): a vectorized batch pre-lookup resolves
   already-known nodes in parallel, and a sequential scan probes only for
   chunk-novel keys, preserving exact assignment order.
6. The shard runs ``ceil(max_count / batch)`` engine rounds, the round
   count agreed across shards with ``lax.pmax`` so every replica advances
   its PRNG stream identically.  The replicas stacked on one device are
   laid out per ``replica_exec`` — one ``jax.vmap``-batched program over
   the replica axis (possible because the trial engine is cond-free
   predicated data flow) or a serializing ``lax.map`` — and the route
   stage's drain-round count is folded into the stage's carried
   telemetry on device (``telem += rounds - 1``).

Because stage 1 depends only on the chunk (never on engine or intern
state), ``ShardedSummarizer`` dispatches chunk k+1's routing — drain
rounds included — while chunk k's engine rounds are still executing: the
steady state is a two-deep pipeline with zero per-chunk host fetches and
zero per-chunk host dict operations.

**Overflow contract.** A lane holds at most ``lane_cap`` changes per drain
round.  Rather than dropping or reordering on overflow, each round routes
only the pending stream prefix before the first overflowing *position*
(``lax.pmin`` across devices) and the next round re-ranks the remainder —
per round at least ``lane_cap`` changes are delivered, so
``ceil(chunk / lane_cap)`` rounds always drain a full chunk
(:func:`router_geometry` computes this bound as ``full_drain_rounds``).
With the default ``max_drain_rounds`` (the full bound) delivery is
statically guaranteed and the caller never has to look at the watermark;
only an explicitly lowered ``max_drain_rounds`` can leave a suffix, which
the caller then feeds through the host-routed path
(:func:`make_bucketed_step`, shared intern state, counted in
``ShardedSummarizer.router_overflows``) — losslessness and stream order
are preserved either way; only the PRNG schedule differs from the
no-overflow trajectory when the host path runs.

**Why both paths intern on device.** Trial randomness depends on local node
ids (they seed the min-hash clustering), so host- and device-routed runs are
bit-identical only if both assign ids in the same per-shard order.  Keeping
the hash -> local-id map in device memory (a
:mod:`~repro.core.engine.hashtable` open-addressing table per shard) gives
both paths one source of truth and makes the host path a true differential
reference for the router.

SPMD hazard audit (docs/KNOWN_ISSUES.md): all gather/scatter here happens
*inside* ``shard_map`` on per-device local arrays, so the GSPMD
concat-of-aligned-slices pattern that miscompiled ``apply_rope`` cannot
arise — the partitioner never sees these concatenations.  The two-stage
split adds no new exposure: the stage boundary passes ``P(axis)``-sharded
bucket arrays between two ``shard_map`` regions without host contact, and
every drain round's scatter/exchange/append runs on per-device locals
inside the ``lax.while_loop`` body.

**Policy threading (PR 8).**  The router is policy-agnostic: routing
keys on label hashes only, and the proposal/objective/commit triple
reaches the engine rounds as static fields on the ``EngineConfig`` the
step factories close over.  ``_STEP_CACHE`` keys on the whole (hashable)
config, so two summarizers with different policy triples — or the same
triple under different ``commit_margin``/``weight_levels`` — never share
a compiled step.  No routing or intern code inspects the triple; the CI
router-stress matrix re-runs this module's suites under a non-default
triple (``REPRO_PROPOSAL``/``REPRO_OBJECTIVE``) to keep it that way.
"""
from __future__ import annotations

import os
from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P

from repro.core.engine.hashtable import (HashTable, ht_find, ht_find_batch,
                                         ht_new, ht_set,
                                         resolve_trial_backend,
                                         trial_backend_scope)
from repro.core.engine.state import EngineConfig, new_state
from repro.core.engine.trial import pwhen, step_fn

INVALID = jnp.int32(-1)

# the device shard key is (h_hi * 2**31 + h_lo) % n_shards computed in
# uint32 residues; (n-1)**2 + (n-1) must stay below 2**31
MAX_SHARDS = 1 << 15

# How engine/intern work is laid out over the shard replicas stacked on one
# device (the n_shards > n_devices production path):
#
# * ``"vmap"`` — one batched program over the stacked replica axis.  The
#   trial engine is cond-free predicated data flow
#   (``core/engine/trial.py``), so vmap pays no both-branches penalty:
#   its predicated regions are phased to carry scalars, not state.
# * ``"map"`` — ``lax.map`` over replicas, serializing them per device
#   but letting each replica's predicated regions short-circuit at
#   runtime.  Also the differential reference (like ``routing="host"``):
#   identical math, independent lowering, bit-identical states.
#
# The default is backend-aware: ``"vmap"`` on accelerator backends — the
# deployment target, where replica lanes vectorize in hardware and a
# future Pallas trial kernel slots in — and ``"map"`` on the XLA *CPU*
# backend, where measurement (docs/KNOWN_ISSUES.md) shows every batched
# ``while`` pays a fixed ~8us dispatch tax (vs <1us unbatched), taxing
# the engine's probe loops and predicated regions ~3-5x over the mapped
# lowering.  Both modes are leaf-bitwise state-identical, so the choice
# is pure performance; REPRO_REPLICA_EXEC overrides (the CI router-stress
# job uses it to cover both).
REPLICA_EXEC_MODES = ("vmap", "map")
DEFAULT_REPLICA_EXEC = os.environ.get(
    "REPRO_REPLICA_EXEC",
    "map" if jax.default_backend() == "cpu" else "vmap")


def _replica_apply(fn, replica_exec: str, *stacked):
    """Run ``fn`` across the leading (stacked-replica) axis of ``stacked``."""
    if replica_exec == "vmap":
        return jax.vmap(fn)(*stacked)
    return jax.lax.map(lambda args: fn(*args), stacked)


# --------------------------------------------------------------------------- #
# device-resident (h_hi, h_lo) -> local-nid interning
# --------------------------------------------------------------------------- #


class InternState(NamedTuple):
    """Per-shard device-resident node intern table.

    Maps 62-bit label hashes — carried as two non-negative ``int32`` words
    ``(hi, lo)``, the native key shape of :class:`HashTable` — to the
    shard's dense local id space ``[0, n_cap)`` that the engine state
    arrays are indexed by.  Ids are assigned first-come-first-served in
    delivery order, which both routing modes reproduce identically.
    ``l2h`` is the reverse map used by ``materialize``/``live_edges`` to
    translate local nids back to label hashes (and, through the host's
    lazily-folded hash -> label map, to caller labels).
    """

    h2l: HashTable      # (h_hi, h_lo) -> local nid
    l2h: jax.Array      # int32[n_cap, 2]: local nid -> (h_hi, h_lo), -1 unset
    n_nodes: jax.Array  # int32: next fresh nid == number interned
    n_dropped: jax.Array  # int32: endpoint interns dropped at full capacity


def intern_new(cfg: EngineConfig) -> InternState:
    cap = 1
    while cap < 4 * cfg.n_cap:   # ~25% max load keeps probes O(1)
        cap <<= 1
    return InternState(
        h2l=ht_new(cap),
        l2h=jnp.full((cfg.n_cap, 2), -1, jnp.int32),
        n_nodes=jnp.int32(0),
        n_dropped=jnp.int32(0),
    )


def drain_telemetry_new(n_dev: int) -> jax.Array:
    """Fresh engine-stage drain-round telemetry carry (``int32[n_dev]``).

    Crash-consistency note (``repro.checkpoint.summary``): the route stage
    is a pure function of the chunk — it has no state to checkpoint.  The
    recovery closure is exactly the engine stage's carried operands: the
    stacked ``EngineState`` + :class:`InternState` and this telemetry
    vector.  The drain loop is pmin/pmax-agreed, so the vector is
    mesh-uniform by construction; a checkpoint can therefore restore it
    onto a mesh with a *different* device count by broadcasting the
    per-run count (``max``) — the basis of the elastic-restore leg.
    """
    return jnp.zeros((n_dev,), jnp.int32)


def drain_telemetry_restore(saved, n_dev: int) -> jax.Array:
    """Re-broadcast a saved (mesh-uniform) drain-round vector onto a mesh
    of ``n_dev`` devices; bitwise-identical when the topology matches."""
    import numpy as np
    count = jnp.int32(np.max(np.asarray(saved))) if np.size(saved) else 0
    return jnp.full((n_dev,), count, jnp.int32)


def _intern_probe(ist: InternState, hi: jax.Array, lo: jax.Array,
                  valid: jax.Array, n_cap: int,
                  ) -> Tuple[InternState, jax.Array]:
    """Sequential-path intern: probe, then insert if fresh (dense FCFS nid).

    Returns ``-1`` when invalid or dropped at capacity.  The intern table
    keys are full-entropy label hashes, so probes start at the prehashed
    position (no re-mix — see ``hashtable.ht_find``).  Cond-free: the
    insert is a masked write under ``take``, so the op vmaps over stacked
    replicas without a both-branches (whole-table-select) penalty.
    """
    h1 = jnp.where(valid, hi, 0)
    h2 = jnp.where(valid, lo, 0)
    slot, found = ht_find(ist.h2l, h1, h2, prehashed=True)
    existing = ist.h2l.val[slot]
    fresh = valid & ~found
    room = ist.n_nodes < n_cap
    take = fresh & room
    nid_new = ist.n_nodes
    nid_w = jnp.minimum(nid_new, n_cap - 1)   # in-bounds slot for the write
    ist = ist._replace(
        h2l=ht_set(ist.h2l, h1, h2, nid_new, prehashed=True, ok=take),
        l2h=ist.l2h.at[nid_w].set(
            jnp.where(take, jnp.stack([h1, h2]), ist.l2h[nid_w])),
        n_nodes=ist.n_nodes + take.astype(jnp.int32),
        n_dropped=ist.n_dropped + (fresh & ~room).astype(jnp.int32))
    nid = jnp.where(found, existing, jnp.where(take, nid_new, INVALID))
    return ist, jnp.where(valid, nid, INVALID)


def _intern_one(ist: InternState, hi: jax.Array, lo: jax.Array,
                valid: jax.Array, pre_found: jax.Array, pre_slot: jax.Array,
                n_cap: int, dense: bool) -> Tuple[InternState, jax.Array]:
    """One intern with a vectorized pre-lookup hint.

    ``pre_found``/``pre_slot`` come from a batch ``ht_find`` against the
    table state at chunk entry.  Linear-probe insertions only ever fill
    EMPTY/TOMB slots — they never relocate existing entries — so a
    pre-found slot stays valid through the scan and the hit path is a
    single gather.  Only chunk-novel keys (or repeats of one) take the
    predicated probe-and-insert region — masked data flow when ``dense``
    (the vmapped-replica lowering), a zero-cost ``pwhen`` short-circuit
    on the all-hits steady state otherwise; never a ``lax.cond``.
    """
    need = valid & ~pre_found

    def miss(carry):
        ist, _ = carry
        return _intern_probe(ist, hi, lo, need, n_cap)

    if dense:
        ist, nid_miss = miss((ist, INVALID))
    else:
        ist, nid_miss = pwhen(need, miss, (ist, INVALID))
    nid = jnp.where(pre_found & valid, ist.h2l.val[pre_slot], nid_miss)
    return ist, jnp.where(valid, nid, INVALID)


def intern_changes(ist: InternState,
                   uh: jax.Array, ul: jax.Array,
                   vh: jax.Array, vl: jax.Array,
                   n_cap: int, dense: bool = False,
                   ) -> Tuple[InternState, jax.Array, jax.Array]:
    """Intern a hashed change sequence in order: ``(ist, u_nid, v_nid)``.

    A change with a dropped endpoint (shard node capacity hit) maps to
    ``(-1, -1)`` — the engine skips it and ``n_dropped`` records the event
    for the host to surface.  The assignment order (hence every nid) is
    identical to a purely sequential intern: the vectorized pre-lookup
    only short-circuits probes for keys already in the table at entry.
    """
    valid = (uh >= 0) & (vh >= 0)

    def batch_find(hi, lo):
        # masked lanes probe key (0, 0) — the garbage-key side of the
        # predication contract; under the pallas backend the whole
        # pre-lookup is one fused probe launch (kernels/ht_probe.py)
        h1 = jnp.where(valid, hi, 0)
        h2 = jnp.where(valid, lo, 0)
        return ht_find_batch(ist.h2l, h1, h2, prehashed=True)

    psu, pfu = batch_find(uh, ul)
    psv, pfv = batch_find(vh, vl)

    def body(ist, ch):
        uh_i, ul_i, vh_i, vl_i, v_i, pfu_i, psu_i, pfv_i, psv_i = ch
        ist, nu = _intern_one(ist, uh_i, ul_i, v_i, pfu_i, psu_i, n_cap,
                              dense)
        ist, nv = _intern_one(ist, vh_i, vl_i, v_i, pfv_i, psv_i, n_cap,
                              dense)
        ok = (nu >= 0) & (nv >= 0)
        return ist, (jnp.where(ok, nu, INVALID), jnp.where(ok, nv, INVALID))

    ist, (u, v) = jax.lax.scan(
        body, ist, (uh, ul, vh, vl, valid, pfu, psu, pfv, psv))
    return ist, u, v


# --------------------------------------------------------------------------- #
# shard keys from hash words
# --------------------------------------------------------------------------- #


def shard_key(uh: jax.Array, ul: jax.Array, vh: jax.Array, vl: jax.Array,
              n_shards: int) -> jax.Array:
    """Canonical-pair shard key ``min(h(u), h(v)) % n_shards`` on device.

    The 62-bit hashes live as two 31-bit words, so the min is
    lexicographic and the modulus composes over uint32 residues:
    ``(hi * 2**31 + lo) % n == ((hi % n) * (2**31 % n) + lo % n) % n``.
    All intermediates stay below ``2**31`` because ``n < MAX_SHARDS``.
    """
    u_le = (uh < vh) | ((uh == vh) & (ul <= vl))
    mh = jnp.where(u_le, uh, vh).astype(jnp.uint32)
    ml = jnp.where(u_le, ul, vl).astype(jnp.uint32)
    m = jnp.uint32(n_shards)
    two31 = jnp.uint32((1 << 31) % n_shards)
    return (((mh % m) * two31 + ml % m) % m).astype(jnp.int32)


# --------------------------------------------------------------------------- #
# host-routed (bucketed) step — the differential reference + overflow path
# --------------------------------------------------------------------------- #


def _state_specs(cfg: EngineConfig, axis: str):
    est_sds = jax.eval_shape(lambda: new_state(cfg))
    ist_sds = jax.eval_shape(lambda: intern_new(cfg))
    return (jax.tree.map(lambda _: P(axis), est_sds),
            jax.tree.map(lambda _: P(axis), ist_sds))


def _donate_argnums(*argnums: int) -> tuple:
    """Donate the given buffers where the backend supports it.

    Donation lets XLA update the (large) stacked engine states — and the
    pipeline's double-buffered routing buckets — in place, so the host can
    stage chunk k+1 while chunk k computes without doubling device memory.
    The CPU backend ignores donation (and warns), so gate on the backend
    instead of spamming every jit call site.
    """
    return () if jax.default_backend() == "cpu" else argnums


# compiled-step memo: ShardedSummarizer constructions with identical
# geometry share one compiled program (EngineConfig is a frozen dataclass
# and Mesh hashes by device assignment, so the key captures everything
# that affects compilation).  Without this, every summarizer pair in a
# differential test recompiles the full shard_map from scratch.
_STEP_CACHE: dict = {}


def make_bucketed_step(cfg: EngineConfig, mesh,
                       replica_exec: str = DEFAULT_REPLICA_EXEC,
                       trial_backend: Optional[str] = None):
    """jit(shard_map) step consuming host-bucketed ``[n_shards, batch]``
    hash-word rounds.  Bucketing/packing happens on the host; interning and
    the engine step run on device, the per-device shard replicas laid out
    by ``replica_exec`` — one vmapped program over the stacked replica axis
    (default; the predicated engine pays no both-branches cost), or a
    serializing ``lax.map`` (the differential reference).  Batched probes
    lower per ``trial_backend`` (resolved against the
    ``REPRO_TRIAL_BACKEND`` default).  Memoized on
    ``(cfg, mesh, replica_exec, trial_backend)``."""
    trial_backend = resolve_trial_backend(trial_backend)
    key = ("bucketed", cfg, mesh, replica_exec, trial_backend)
    if key in _STEP_CACHE:
        return _STEP_CACHE[key]
    axis = mesh.axis_names[0]
    est_specs, ist_specs = _state_specs(cfg, axis)
    dense = replica_exec == "vmap"   # vmap lanes want pure data flow

    def one(est, ist, uh, ul, vh, vl, ins):
        ist, u, v = intern_changes(ist, uh, ul, vh, vl, cfg.n_cap, dense)
        return step_fn(est, u, v, ins != 0, cfg, dense), ist

    def local(est, ist, uh, ul, vh, vl, ins):
        # scope entered inside the traced body: the probe call sites bake
        # in the backend while this function traces under jit
        with trial_backend_scope(trial_backend):
            return _replica_apply(one, replica_exec,
                                  est, ist, uh, ul, vh, vl, ins)

    fn = jax.jit(shard_map(
        local, mesh=mesh,
        in_specs=(est_specs, ist_specs) + (P(axis),) * 5,
        out_specs=(est_specs, ist_specs), check_rep=False),
        donate_argnums=_donate_argnums(0, 1))
    _STEP_CACHE[key] = fn
    return fn


# --------------------------------------------------------------------------- #
# stage 1: route — shard keys + all_to_all drain rounds (state-independent)
# --------------------------------------------------------------------------- #


class RouterGeometry(NamedTuple):
    """Resolved static geometry of one compiled router program.

    ``static_no_overflow`` proves a single exchange round always suffices
    (``lane_cap == n_in``: a lane can never receive more than its source
    slice), in which case the compiled program carries no overflow watermark
    at all.  ``drain_guaranteed`` is the weaker — and default — proof that
    ``max_drain_rounds`` rounds always deliver the whole chunk (each
    non-final round delivers at least ``lane_cap`` changes, so
    ``full_drain_rounds = ceil(chunk / lane_cap)`` is a delivery
    guarantee); when it holds the caller never needs to inspect the
    watermark, which is what lets ``ShardedSummarizer`` elide the per-chunk
    host sync — and, since the route stage depends on nothing but the
    chunk, pipeline chunk k+1's routing under chunk k's engine rounds.
    """

    n_dev: int                 # mesh devices
    n_loc: int                 # shard replicas per device
    n_in: int                  # stream positions per source device
    lane_cap: int              # slots per (source, shard) lane per round
    max_drain_rounds: int      # compiled bound on exchange rounds
    full_drain_rounds: int     # rounds that provably deliver a full chunk
    acc_cap: int               # per-shard receive-bucket capacity
    static_no_overflow: bool   # lane_cap == n_in: one round, no watermark
    drain_guaranteed: bool     # max_drain_rounds >= full_drain_rounds


def router_geometry(mesh, n_shards: int, chunk: int, lane_cap: int,
                    max_drain_rounds: Optional[int] = None) -> RouterGeometry:
    """Resolve the router's static knobs for a fixed (mesh, chunk) geometry."""
    n_dev = int(mesh.devices.size)
    if chunk % n_dev != 0:
        raise ValueError(f"chunk={chunk} must be divisible by n_dev={n_dev}")
    if n_shards % n_dev != 0:
        raise ValueError(
            f"n_shards={n_shards} must be a multiple of n_dev={n_dev}")
    if n_shards >= MAX_SHARDS:
        raise ValueError(
            f"n_shards={n_shards} must be < {MAX_SHARDS} (the device shard "
            f"key composes 31-bit hash words over uint32 residues)")
    n_loc = n_shards // n_dev
    n_in = chunk // n_dev            # stream positions per source device
    lane_cap = min(int(lane_cap), n_in)  # a lane can't exceed its source slice
    if lane_cap < 1:
        raise ValueError(f"lane_cap must be >= 1, got {lane_cap}")
    static_no_overflow = lane_cap == n_in
    # each non-final drain round delivers >= lane_cap changes (the blocking
    # lane sends a full lane), so this many rounds always drain the chunk
    full_drain = 1 if static_no_overflow else -(-chunk // lane_cap)
    if max_drain_rounds is None:
        max_drain_rounds = full_drain
    max_drain_rounds = max(1, min(int(max_drain_rounds), full_drain))
    r_cap = n_dev * lane_cap         # max deliverable per shard per round
    acc_cap = min(chunk, max_drain_rounds * r_cap)
    return RouterGeometry(
        n_dev=n_dev, n_loc=n_loc, n_in=n_in, lane_cap=lane_cap,
        max_drain_rounds=max_drain_rounds, full_drain_rounds=full_drain,
        acc_cap=acc_cap, static_no_overflow=static_no_overflow,
        drain_guaranteed=max_drain_rounds >= full_drain)


def make_route_step(mesh, n_shards: int, chunk: int, lane_cap: int,
                    max_drain_rounds: Optional[int] = None):
    """Compile the state-independent routing stage for a fixed geometry.

    Returns ``(route, geometry)`` where ``route`` is a jitted
    ``(uh, ul, vh, vl, ins) -> (buckets, counts, delivered, rounds)``: the
    inputs are flat ``[chunk]`` hash-word change arrays (``-1`` padded);
    ``buckets`` is the 5-tuple of per-shard ``[n_shards, acc_cap]`` bucket
    arrays in delivery (== stream) order; ``counts`` is ``[n_shards]``
    delivered-change counts; ``delivered`` is, per device, the first
    stream position NOT routed when ``max_drain_rounds`` ran out
    (``chunk`` when everything was delivered — always, when
    ``geometry.drain_guaranteed``); ``rounds`` is the number of exchange
    rounds the drain loop ran (1 = no overflow anywhere).

    The stage reads no engine or intern state, so its dispatch for chunk
    k+1 can overlap chunk k's engine stage.  Memoized on the geometry key.
    """
    key = ("route", mesh, n_shards, chunk, lane_cap, max_drain_rounds)
    if key in _STEP_CACHE:
        return _STEP_CACHE[key]
    axis = mesh.axis_names[0]
    geom = router_geometry(mesh, n_shards, chunk, lane_cap, max_drain_rounds)
    n_dev, n_loc, n_in = geom.n_dev, geom.n_loc, geom.n_in
    lane_cap, acc_cap = geom.lane_cap, geom.acc_cap
    r_cap = n_dev * lane_cap

    def local(uh, ul, vh, vl, ins):
        # uh/ul/vh/vl/ins local [n_in]
        me = jax.lax.axis_index(axis)
        valid = (uh >= 0) & (vh >= 0)
        dest = jnp.where(valid, shard_key(uh, ul, vh, vl, n_shards), n_shards)
        pos = me * n_in + jnp.arange(n_in, dtype=jnp.int32)
        payload = jnp.stack(
            [uh, ul, vh, vl, ins.astype(jnp.int32)], axis=-1)
        rows = jnp.arange(n_loc, dtype=jnp.int32)[:, None]
        sid = jnp.arange(n_shards, dtype=jnp.int32)[None]

        def drain_round(carry):
            r, delivered, acc, counts = carry
            pending = valid & (pos >= delivered)

            # rank of each pending change within its (source, dest) lane;
            # order-stable (monotone in stream position)
            onehot = (dest[:, None] == sid) & pending[:, None]
            cum = jnp.cumsum(onehot.astype(jnp.int32), axis=0)
            rank = jnp.take_along_axis(
                cum, jnp.clip(dest, 0, n_shards - 1)[:, None],
                axis=1)[:, 0] - 1

            # capacity bound: route only the pending stream prefix before
            # the first overflowing position, so the delivered set is always
            # a stream prefix and per-shard order survives multi-round drain
            if geom.static_no_overflow:
                first = jnp.int32(chunk)   # provably no overflow: no pmin
            else:
                over = pending & (rank >= lane_cap)
                my_first = jnp.min(jnp.where(over, pos, jnp.int32(chunk)))
                first = jax.lax.pmin(my_first, axis)
            keep = pending & (rank < lane_cap) & (pos < first)

            # scatter kept changes into the [n_dev, n_loc, lane_cap] lanes
            dd = jnp.where(keep, dest // n_loc, n_dev)  # OOB index -> drop
            dl = jnp.where(keep, dest % n_loc, 0)
            rk = jnp.where(keep, rank, 0)
            send = jnp.full((n_dev, n_loc, lane_cap, 5), -1, jnp.int32)
            send = send.at[dd, dl, rk].set(payload, mode="drop")

            # exchange: recv[j, l] = source j's lane for my local shard l
            recv = jax.lax.all_to_all(send, axis, split_axis=0,
                                      concat_axis=0, tiled=True)
            # source-major flatten per shard == global stream order
            recv = jnp.swapaxes(recv, 0, 1).reshape(n_loc, r_cap, 5)

            # stable compaction, appended at each shard's bucket watermark
            rvalid = recv[..., 0] >= 0
            cpos = jnp.cumsum(rvalid.astype(jnp.int32), axis=1) - 1
            idx = jnp.where(rvalid, counts[:, None] + cpos, acc_cap)
            acc = acc.at[rows, idx].set(recv, mode="drop")
            counts = counts + rvalid.sum(axis=1).astype(jnp.int32)
            return r + 1, first, acc, counts

        # drain until the whole chunk is delivered or the round budget is
        # spent; the loop condition is pmin-agreed, hence mesh-uniform
        init = (jnp.int32(0), jnp.int32(0),
                jnp.full((n_loc, acc_cap, 5), -1, jnp.int32),
                jnp.zeros((n_loc,), jnp.int32))
        rounds, delivered, acc, counts = jax.lax.while_loop(
            lambda c: (c[1] < chunk) & (c[0] < geom.max_drain_rounds),
            drain_round, init)
        return (acc[..., 0], acc[..., 1], acc[..., 2], acc[..., 3],
                acc[..., 4], counts, delivered[None], rounds[None])

    fn = jax.jit(shard_map(
        local, mesh=mesh,
        in_specs=(P(axis),) * 5,
        out_specs=(P(axis),) * 8, check_rep=False))
    _STEP_CACHE[key] = (fn, geom)
    return fn, geom


# --------------------------------------------------------------------------- #
# stage 2: engine — intern the routed buckets, run pmax-agreed engine rounds
# --------------------------------------------------------------------------- #


def make_engine_step(cfg: EngineConfig, mesh, n_shards: int, acc_cap: int,
                     replica_exec: str = DEFAULT_REPLICA_EXEC,
                     trial_backend: Optional[str] = None):
    """Compile the state-carrying engine stage for routed buckets.

    ``(est, ist, telem, a_uh, a_ul, a_vh, a_vl, a_ins, counts, rounds)
    -> (est, ist, telem)``: interns each shard's ``[n_shards, acc_cap]``
    bucket (delivery order == stream order) and runs
    ``pmax(ceil(max_count / batch))`` engine rounds so every replica's
    PRNG advances in lockstep.  The shard replicas stacked on one device
    are laid out by ``replica_exec``: one vmapped program over the replica
    axis (default), or a serializing ``lax.map`` (the differential
    reference).

    ``telem`` is the carried routing telemetry (``int32[n_dev]``, equal
    across devices): the stage folds the route stage's drain-round count
    ``rounds`` into it on device (``telem += rounds - 1``), so the host
    never buffers per-chunk round counts.  The engine/intern/telemetry
    states AND the bucket buffers are donated on non-CPU backends — the
    buckets are the pipeline's double buffer, consumed exactly once.

    Memoized on ``(cfg, mesh, n_shards, acc_cap, replica_exec,
    trial_backend)``.
    """
    trial_backend = resolve_trial_backend(trial_backend)
    key = ("engine", cfg, mesh, n_shards, acc_cap, replica_exec,
           trial_backend)
    if key in _STEP_CACHE:
        return _STEP_CACHE[key]
    axis = mesh.axis_names[0]
    n_dev = int(mesh.devices.size)
    n_loc = n_shards // n_dev
    b = cfg.batch
    est_specs, ist_specs = _state_specs(cfg, axis)
    dense = replica_exec == "vmap"   # vmap lanes want pure data flow

    def local(est, ist, telem, *bucket_args):
        # probe backend baked in at trace time (same idiom as the
        # bucketed step)
        with trial_backend_scope(trial_backend):
            return _local(est, ist, telem, *bucket_args)

    def _local(est, ist, telem, a_uh, a_ul, a_vh, a_vl, a_ins, counts,
               rounds):
        # est/ist stacked [n_loc, ...]; buckets [n_loc, acc_cap];
        # telem/rounds [1] (device-local slice of the [n_dev] array)
        # intern each shard's whole bucket up front — the same order host
        # bucketing interns in, so both paths assign identical local ids
        def int_one(ist_l, uh_l, ul_l, vh_l, vl_l):
            return intern_changes(ist_l, uh_l, ul_l, vh_l, vl_l,
                                  cfg.n_cap, dense)

        ist, u_all, v_all = _replica_apply(
            int_one, replica_exec, ist, a_uh, a_ul, a_vh, a_vl)

        # one spare round of padding so dynamic_slice never clamps
        u_all = jnp.concatenate(
            [u_all, jnp.full((n_loc, b), -1, jnp.int32)], axis=1)
        v_all = jnp.concatenate(
            [v_all, jnp.full((n_loc, b), -1, jnp.int32)], axis=1)
        i_all = jnp.concatenate(
            [a_ins, jnp.zeros((n_loc, b), jnp.int32)], axis=1)

        # every shard steps the same number of rounds (uniform PRNG advance,
        # matching the host path's ceil(max_bucket / batch) schedule)
        erounds = jax.lax.pmax(jnp.max((counts + b - 1) // b), axis)

        def round_body(carry):
            r, est = carry

            def one(est_l, u_l, v_l, i_l):
                us = jax.lax.dynamic_slice(u_l, (r * b,), (b,))
                vs = jax.lax.dynamic_slice(v_l, (r * b,), (b,))
                fs = jax.lax.dynamic_slice(i_l, (r * b,), (b,)) != 0
                return step_fn(est_l, us, vs, fs, cfg, dense)

            return r + 1, _replica_apply(one, replica_exec,
                                         est, u_all, v_all, i_all)

        _, est = jax.lax.while_loop(
            lambda c: c[0] < erounds, round_body, (jnp.int32(0), est))
        # drain-round telemetry: extra exchange rounds beyond the first,
        # accumulated device-side (rounds is mesh-uniform by construction)
        return est, ist, telem + rounds - 1

    fn = jax.jit(shard_map(
        local, mesh=mesh,
        in_specs=(est_specs, ist_specs) + (P(axis),) * 8,
        out_specs=(est_specs, ist_specs, P(axis)), check_rep=False),
        donate_argnums=_donate_argnums(0, 1, 2, 3, 4, 5, 6, 7))
    _STEP_CACHE[key] = fn
    return fn


def default_lane_cap(chunk: int, n_dev: int, n_shards: int,
                     batch: int) -> int:
    """4x-headroom lane size over the balanced expectation, floored at one
    engine batch and capped at the source slice (beyond which a lane cannot
    fill) — with the default drain bound the router then delivers any chunk
    fully on device, and a key-skewed chunk costs extra drain rounds rather
    than a host replay."""
    balanced = -(-chunk // (n_dev * n_shards))   # ceil
    return min(max(batch, 4 * balanced), chunk // n_dev)
