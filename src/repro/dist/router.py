"""Device-side stream router for edge-partitioned summarization.

:class:`~repro.core.engine.api.ShardedSummarizer` partitions the edge stream
over a fleet of engine replicas by canonical-pair key
``min(gid(u), gid(v)) % n_shards``.  Until this module existed the routing
ran on the host — a Python loop bucketing every change — so aggregate
*capacity* scaled with the shard count while *throughput* did not.  The
router moves the partition-and-exchange onto the devices:

1. The host hands the router one flat, gid-encoded chunk of changes
   (``-1``-padded to a fixed ``chunk`` length, split contiguously over the
   mesh so device ``d`` holds stream positions ``[d*n_in, (d+1)*n_in)``).
2. Each source device computes the shard key of its changes and scatters
   them into a capacity-bounded send buffer of ``lane_cap`` slots per
   (source device, destination shard) lane.
3. One ``lax.all_to_all`` inside the existing ``shard_map`` region delivers
   every lane to the device owning its destination shard; the receiver
   compacts the lanes source-major, which reconstructs global stream order
   (source slices are contiguous in the stream and ranks preserve order
   within a lane).
4. If some lane overflowed, steps 2-3 repeat as a bounded on-device
   **drain loop** (``lax.while_loop``): each round routes the pending
   stream prefix up to the first still-overflowing position (agreed with
   ``lax.pmin``) and appends the deliveries to the per-shard buckets, so
   multi-round delivery is lossless and order-preserving without any host
   round-trip.
5. Each shard interns the received gids into its dense local id space
   (:class:`InternState`, first-come-first-served — the same order host
   bucketing would produce) and runs ``ceil(max_count / batch)`` engine
   rounds, the round count agreed across shards with ``lax.pmax`` so every
   replica advances its PRNG stream identically.

**Overflow contract.** A lane holds at most ``lane_cap`` changes per drain
round.  Rather than dropping or reordering on overflow, each round routes
only the pending stream prefix before the first overflowing *position*
(``lax.pmin`` across devices) and the next round re-ranks the remainder —
per round at least ``lane_cap`` changes are delivered, so
``ceil(chunk / lane_cap)`` rounds always drain a full chunk
(:func:`router_geometry` computes this bound as ``full_drain_rounds``).
With the default ``max_drain_rounds`` (the full bound) delivery is
statically guaranteed and the caller never has to look at the watermark;
only an explicitly lowered ``max_drain_rounds`` can leave a suffix, which
the caller then feeds through the host-routed path
(:func:`make_bucketed_step`, shared intern state, counted in
``ShardedSummarizer.router_overflows``) — losslessness and stream order
are preserved either way; only the PRNG schedule differs from the
no-overflow trajectory when the host path runs.

**Why both paths intern on device.** Trial randomness depends on local node
ids (they seed the min-hash clustering), so host- and device-routed runs are
bit-identical only if both assign ids in the same per-shard order.  Keeping
the gid -> local-id map in device memory (a :mod:`~repro.core.engine.hashtable`
open-addressing table per shard) gives both paths one source of truth and
makes the host path a true differential reference for the router.

SPMD hazard audit (docs/KNOWN_ISSUES.md): all gather/scatter here happens
*inside* ``shard_map`` on per-device local arrays, so the GSPMD
concat-of-aligned-slices pattern that miscompiled ``apply_rope`` cannot
arise — the partitioner never sees these concatenations.  The drain loop
adds no new exposure: every round's scatter/exchange/append runs on the
same per-device locals inside the ``lax.while_loop`` body.
"""
from __future__ import annotations

from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P

from repro.core.engine.hashtable import HashTable, ht_find, ht_new, ht_set
from repro.core.engine.state import EngineConfig, new_state
from repro.core.engine.trial import step_fn

INVALID = jnp.int32(-1)


# --------------------------------------------------------------------------- #
# device-resident gid -> local-nid interning
# --------------------------------------------------------------------------- #


class InternState(NamedTuple):
    """Per-shard device-resident node intern table.

    Maps global ids (gids, assigned by the host in label-encounter order) to
    the shard's dense local id space ``[0, n_cap)`` that the engine state
    arrays are indexed by.  ``l2g`` is the reverse map used by
    ``materialize``/``live_edges`` to translate summaries back to caller
    labels, so delivery order (which fixes nid assignment) is fully
    recoverable on the host.
    """

    g2l: HashTable      # (gid, 0) -> local nid
    l2g: jax.Array      # int32[n_cap]: local nid -> gid (-1 unset)
    n_nodes: jax.Array  # int32: next fresh nid == number interned
    n_dropped: jax.Array  # int32: endpoint interns dropped at full capacity


def intern_new(cfg: EngineConfig) -> InternState:
    cap = 1
    while cap < 4 * cfg.n_cap:   # ~25% max load keeps probes O(1)
        cap <<= 1
    return InternState(
        g2l=ht_new(cap),
        l2g=jnp.full((cfg.n_cap,), -1, jnp.int32),
        n_nodes=jnp.int32(0),
        n_dropped=jnp.int32(0),
    )


def _intern_one(ist: InternState, gid: jax.Array, valid: jax.Array,
                n_cap: int) -> Tuple[InternState, jax.Array]:
    """Dense first-come-first-served nid for gid; -1 when invalid/dropped."""
    g = jnp.where(valid, gid, 0)
    slot, found = ht_find(ist.g2l, g, 0)
    existing = ist.g2l.val[slot]
    fresh = valid & ~found
    room = ist.n_nodes < n_cap
    take = fresh & room
    nid_new = ist.n_nodes

    def ins(i: InternState) -> InternState:
        return i._replace(
            g2l=ht_set(i.g2l, g, 0, nid_new),
            l2g=i.l2g.at[nid_new].set(g),
            n_nodes=i.n_nodes + 1)

    ist = jax.lax.cond(take, ins, lambda i: i, ist)
    ist = ist._replace(
        n_dropped=ist.n_dropped + (fresh & ~room).astype(jnp.int32))
    nid = jnp.where(found, existing, jnp.where(take, nid_new, INVALID))
    return ist, jnp.where(valid, nid, INVALID)


def intern_changes(ist: InternState, gu: jax.Array, gv: jax.Array,
                   n_cap: int) -> Tuple[InternState, jax.Array, jax.Array]:
    """Intern a change sequence in order: ``(ist, u_nid, v_nid)``.

    A change with a dropped endpoint (shard node capacity hit) maps to
    ``(-1, -1)`` — the engine skips it and ``n_dropped`` records the event
    for the host to surface.
    """

    def body(ist, ch):
        gu_i, gv_i = ch
        valid = (gu_i >= 0) & (gv_i >= 0)
        ist, nu = _intern_one(ist, gu_i, valid, n_cap)
        ist, nv = _intern_one(ist, gv_i, valid, n_cap)
        ok = (nu >= 0) & (nv >= 0)
        return ist, (jnp.where(ok, nu, INVALID), jnp.where(ok, nv, INVALID))

    ist, (u, v) = jax.lax.scan(body, ist, (gu, gv))
    return ist, u, v


# --------------------------------------------------------------------------- #
# host-routed (bucketed) step — the differential reference + overflow path
# --------------------------------------------------------------------------- #


def _state_specs(cfg: EngineConfig, axis: str):
    est_sds = jax.eval_shape(lambda: new_state(cfg))
    ist_sds = jax.eval_shape(lambda: intern_new(cfg))
    return (jax.tree.map(lambda _: P(axis), est_sds),
            jax.tree.map(lambda _: P(axis), ist_sds))


def _donate_argnums() -> tuple:
    """Donate the engine/intern buffers where the backend supports it.

    Donation lets XLA update the (large) stacked engine states in place, so
    the host can stage chunk k+1 while chunk k computes without doubling
    device memory.  The CPU backend ignores donation (and warns), so gate
    on the backend instead of spamming every jit call site.
    """
    return () if jax.default_backend() == "cpu" else (0, 1)


# compiled-step memo: ShardedSummarizer constructions with identical
# geometry share one compiled program (EngineConfig is a frozen dataclass
# and Mesh hashes by device assignment, so the key captures everything
# that affects compilation).  Without this, every summarizer pair in a
# differential test recompiles the full shard_map from scratch.
_STEP_CACHE: dict = {}


def make_bucketed_step(cfg: EngineConfig, mesh):
    """jit(shard_map) step consuming host-bucketed ``[n_shards, batch]`` gid
    rounds.  Bucketing/packing happens on the host; interning and the engine
    step run on device (``lax.map`` lays multiple shard replicas per device,
    keeping the engine's control flow intact instead of paying vmap's
    both-branches cost).  Memoized on ``(cfg, mesh)``."""
    key = ("bucketed", cfg, mesh)
    if key in _STEP_CACHE:
        return _STEP_CACHE[key]
    axis = mesh.axis_names[0]
    est_specs, ist_specs = _state_specs(cfg, axis)

    def one(args):
        est, ist, gu, gv, ins = args
        ist, u, v = intern_changes(ist, gu, gv, cfg.n_cap)
        return step_fn(est, u, v, ins != 0, cfg), ist

    def local(est, ist, gu, gv, ins):
        return jax.lax.map(one, (est, ist, gu, gv, ins))

    fn = jax.jit(shard_map(
        local, mesh=mesh,
        in_specs=(est_specs, ist_specs, P(axis), P(axis), P(axis)),
        out_specs=(est_specs, ist_specs), check_rep=False),
        donate_argnums=_donate_argnums())
    _STEP_CACHE[key] = fn
    return fn


# --------------------------------------------------------------------------- #
# device-routed step — shard keys, all_to_all drain rounds, engine rounds
# --------------------------------------------------------------------------- #


class RouterGeometry(NamedTuple):
    """Resolved static geometry of one compiled router program.

    ``static_no_overflow`` proves a single exchange round always suffices
    (``lane_cap == n_in``: a lane can never receive more than its source
    slice), in which case the compiled program carries no overflow watermark
    at all.  ``drain_guaranteed`` is the weaker — and default — proof that
    ``max_drain_rounds`` rounds always deliver the whole chunk (each
    non-final round delivers at least ``lane_cap`` changes, so
    ``full_drain_rounds = ceil(chunk / lane_cap)`` is a delivery
    guarantee); when it holds the caller never needs to inspect the
    watermark, which is what lets ``ShardedSummarizer`` elide the per-chunk
    host sync.
    """

    n_dev: int                 # mesh devices
    n_loc: int                 # shard replicas per device
    n_in: int                  # stream positions per source device
    lane_cap: int              # slots per (source, shard) lane per round
    max_drain_rounds: int      # compiled bound on exchange rounds
    full_drain_rounds: int     # rounds that provably deliver a full chunk
    acc_cap: int               # per-shard receive-bucket capacity
    static_no_overflow: bool   # lane_cap == n_in: one round, no watermark
    drain_guaranteed: bool     # max_drain_rounds >= full_drain_rounds


def router_geometry(mesh, n_shards: int, chunk: int, lane_cap: int,
                    max_drain_rounds: Optional[int] = None) -> RouterGeometry:
    """Resolve the router's static knobs for a fixed (mesh, chunk) geometry."""
    n_dev = int(mesh.devices.size)
    if chunk % n_dev != 0:
        raise ValueError(f"chunk={chunk} must be divisible by n_dev={n_dev}")
    if n_shards % n_dev != 0:
        raise ValueError(
            f"n_shards={n_shards} must be a multiple of n_dev={n_dev}")
    n_loc = n_shards // n_dev
    n_in = chunk // n_dev            # stream positions per source device
    lane_cap = min(int(lane_cap), n_in)  # a lane can't exceed its source slice
    if lane_cap < 1:
        raise ValueError(f"lane_cap must be >= 1, got {lane_cap}")
    static_no_overflow = lane_cap == n_in
    # each non-final drain round delivers >= lane_cap changes (the blocking
    # lane sends a full lane), so this many rounds always drain the chunk
    full_drain = 1 if static_no_overflow else -(-chunk // lane_cap)
    if max_drain_rounds is None:
        max_drain_rounds = full_drain
    max_drain_rounds = max(1, min(int(max_drain_rounds), full_drain))
    r_cap = n_dev * lane_cap         # max deliverable per shard per round
    acc_cap = min(chunk, max_drain_rounds * r_cap)
    return RouterGeometry(
        n_dev=n_dev, n_loc=n_loc, n_in=n_in, lane_cap=lane_cap,
        max_drain_rounds=max_drain_rounds, full_drain_rounds=full_drain,
        acc_cap=acc_cap, static_no_overflow=static_no_overflow,
        drain_guaranteed=max_drain_rounds >= full_drain)


def make_routed_step(cfg: EngineConfig, mesh, n_shards: int, chunk: int,
                     lane_cap: int,
                     max_drain_rounds: Optional[int] = None):
    """Compile the device-resident router for a fixed geometry.

    Returns ``(step, geometry)`` where ``step`` is a jitted
    ``(est, ist, gu, gv, ins) -> (est, ist, delivered, rounds)``: the inputs
    are the stacked per-shard states plus flat ``[chunk]`` gid-encoded
    change arrays (``-1`` padded); ``delivered`` is, per device, the first
    stream position NOT routed when ``max_drain_rounds`` ran out
    (``chunk`` when everything was delivered — always, when
    ``geometry.drain_guaranteed``); ``rounds`` is the number of exchange
    rounds the drain loop ran (1 = no overflow anywhere).

    Memoized on the full geometry key.
    """
    key = ("routed", cfg, mesh, n_shards, chunk, lane_cap, max_drain_rounds)
    if key in _STEP_CACHE:
        return _STEP_CACHE[key]
    axis = mesh.axis_names[0]
    geom = router_geometry(mesh, n_shards, chunk, lane_cap, max_drain_rounds)
    n_dev, n_loc, n_in = geom.n_dev, geom.n_loc, geom.n_in
    lane_cap, acc_cap = geom.lane_cap, geom.acc_cap
    r_cap = n_dev * lane_cap
    b = cfg.batch
    est_specs, ist_specs = _state_specs(cfg, axis)

    def local(est, ist, gu, gv, ins):
        # est/ist stacked [n_loc, ...]; gu/gv/ins local [n_in]
        me = jax.lax.axis_index(axis)
        valid = (gu >= 0) & (gv >= 0)
        dest = jnp.where(valid, jnp.minimum(gu, gv) % n_shards, n_shards)
        pos = me * n_in + jnp.arange(n_in, dtype=jnp.int32)
        payload = jnp.stack([gu, gv, ins.astype(jnp.int32)], axis=-1)
        rows = jnp.arange(n_loc, dtype=jnp.int32)[:, None]
        sid = jnp.arange(n_shards, dtype=jnp.int32)[None]

        def drain_round(carry):
            r, delivered, a_gu, a_gv, a_ins, counts = carry
            pending = valid & (pos >= delivered)

            # rank of each pending change within its (source, dest) lane;
            # order-stable (monotone in stream position)
            onehot = (dest[:, None] == sid) & pending[:, None]
            cum = jnp.cumsum(onehot.astype(jnp.int32), axis=0)
            rank = jnp.take_along_axis(
                cum, jnp.clip(dest, 0, n_shards - 1)[:, None],
                axis=1)[:, 0] - 1

            # capacity bound: route only the pending stream prefix before
            # the first overflowing position, so the delivered set is always
            # a stream prefix and per-shard order survives multi-round drain
            if geom.static_no_overflow:
                first = jnp.int32(chunk)   # provably no overflow: no pmin
            else:
                over = pending & (rank >= lane_cap)
                my_first = jnp.min(jnp.where(over, pos, jnp.int32(chunk)))
                first = jax.lax.pmin(my_first, axis)
            keep = pending & (rank < lane_cap) & (pos < first)

            # scatter kept changes into the [n_dev, n_loc, lane_cap] lanes
            dd = jnp.where(keep, dest // n_loc, n_dev)  # OOB index -> drop
            dl = jnp.where(keep, dest % n_loc, 0)
            rk = jnp.where(keep, rank, 0)
            send = jnp.full((n_dev, n_loc, lane_cap, 3), -1, jnp.int32)
            send = send.at[dd, dl, rk].set(payload, mode="drop")

            # exchange: recv[j, l] = source j's lane for my local shard l
            recv = jax.lax.all_to_all(send, axis, split_axis=0,
                                      concat_axis=0, tiled=True)
            # source-major flatten per shard == global stream order
            recv = jnp.swapaxes(recv, 0, 1).reshape(n_loc, r_cap, 3)
            rgu, rgv, rins = recv[..., 0], recv[..., 1], recv[..., 2]

            # stable compaction, appended at each shard's bucket watermark
            rvalid = rgu >= 0
            cpos = jnp.cumsum(rvalid.astype(jnp.int32), axis=1) - 1
            idx = jnp.where(rvalid, counts[:, None] + cpos, acc_cap)
            a_gu = a_gu.at[rows, idx].set(rgu, mode="drop")
            a_gv = a_gv.at[rows, idx].set(rgv, mode="drop")
            a_ins = a_ins.at[rows, idx].set(rins, mode="drop")
            counts = counts + rvalid.sum(axis=1).astype(jnp.int32)
            return r + 1, first, a_gu, a_gv, a_ins, counts

        # drain until the whole chunk is delivered or the round budget is
        # spent; the loop condition is pmin-agreed, hence mesh-uniform
        init = (jnp.int32(0), jnp.int32(0),
                jnp.full((n_loc, acc_cap), -1, jnp.int32),
                jnp.full((n_loc, acc_cap), -1, jnp.int32),
                jnp.zeros((n_loc, acc_cap), jnp.int32),
                jnp.zeros((n_loc,), jnp.int32))
        rounds, delivered, a_gu, a_gv, a_ins, counts = jax.lax.while_loop(
            lambda c: (c[1] < chunk) & (c[0] < geom.max_drain_rounds),
            drain_round, init)

        # intern each shard's whole bucket up front — the same order host
        # bucketing interns in, so both paths assign identical local ids
        def int_one(args):
            ist_l, gu_l, gv_l = args
            return intern_changes(ist_l, gu_l, gv_l, cfg.n_cap)

        ist, u_all, v_all = jax.lax.map(int_one, (ist, a_gu, a_gv))

        # one spare round of padding so dynamic_slice never clamps
        u_all = jnp.concatenate(
            [u_all, jnp.full((n_loc, b), -1, jnp.int32)], axis=1)
        v_all = jnp.concatenate(
            [v_all, jnp.full((n_loc, b), -1, jnp.int32)], axis=1)
        i_all = jnp.concatenate(
            [a_ins, jnp.zeros((n_loc, b), jnp.int32)], axis=1)

        # every shard steps the same number of rounds (uniform PRNG advance,
        # matching the host path's ceil(max_bucket / batch) schedule)
        erounds = jax.lax.pmax(jnp.max((counts + b - 1) // b), axis)

        def round_body(carry):
            r, est = carry

            def one(args):
                est_l, u_l, v_l, i_l = args
                us = jax.lax.dynamic_slice(u_l, (r * b,), (b,))
                vs = jax.lax.dynamic_slice(v_l, (r * b,), (b,))
                fs = jax.lax.dynamic_slice(i_l, (r * b,), (b,)) != 0
                return step_fn(est_l, us, vs, fs, cfg)

            return r + 1, jax.lax.map(one, (est, u_all, v_all, i_all))

        _, est = jax.lax.while_loop(
            lambda c: c[0] < erounds, round_body, (jnp.int32(0), est))
        return est, ist, delivered[None], rounds[None]

    fn = jax.jit(shard_map(
        local, mesh=mesh,
        in_specs=(est_specs, ist_specs, P(axis), P(axis), P(axis)),
        out_specs=(est_specs, ist_specs, P(axis), P(axis)),
        check_rep=False), donate_argnums=_donate_argnums())
    _STEP_CACHE[key] = (fn, geom)
    return fn, geom


def default_lane_cap(chunk: int, n_dev: int, n_shards: int,
                     batch: int) -> int:
    """4x-headroom lane size over the balanced expectation, floored at one
    engine batch and capped at the source slice (beyond which a lane cannot
    fill) — with the default drain bound the router then delivers any chunk
    fully on device, and a key-skewed chunk costs extra drain rounds rather
    than a host replay."""
    balanced = -(-chunk // (n_dev * n_shards))   # ceil
    return min(max(batch, 4 * balanced), chunk // n_dev)
