"""Device-side stream router for edge-partitioned summarization.

:class:`~repro.core.engine.api.ShardedSummarizer` partitions the edge stream
over a fleet of engine replicas by canonical-pair key
``min(gid(u), gid(v)) % n_shards``.  Until this module existed the routing
ran on the host — a Python loop bucketing every change — so aggregate
*capacity* scaled with the shard count while *throughput* did not.  The
router moves the partition-and-exchange onto the devices:

1. The host hands the router one flat, gid-encoded chunk of changes
   (``-1``-padded to a fixed ``chunk`` length, split contiguously over the
   mesh so device ``d`` holds stream positions ``[d*n_in, (d+1)*n_in)``).
2. Each source device computes the shard key of its changes and scatters
   them into a capacity-bounded send buffer of ``lane_cap`` slots per
   (source device, destination shard) lane.
3. One ``lax.all_to_all`` inside the existing ``shard_map`` region delivers
   every lane to the device owning its destination shard; the receiver
   compacts the lanes source-major, which reconstructs global stream order
   (source slices are contiguous in the stream and ranks preserve order
   within a lane).
4. Each shard interns the received gids into its dense local id space
   (:class:`InternState`, first-come-first-served — the same order host
   bucketing would produce) and runs ``ceil(max_count / batch)`` engine
   rounds, the round count agreed across shards with ``lax.pmax`` so every
   replica advances its PRNG stream identically.

**Overflow contract.** A lane holds at most ``lane_cap`` changes per routed
chunk.  Rather than dropping or reordering on overflow, the router computes
the first overflowing *stream position* (``lax.pmin`` across devices), routes
only the prefix before it, and reports that position; the caller then feeds
the suffix through the host-routed path (:func:`make_bucketed_step`), which
shares the device-side intern state, so losslessness and stream order are
preserved — only the PRNG schedule differs from the no-overflow trajectory.
Overflowed changes are counted in ``ShardedSummarizer.router_overflows``.

**Why both paths intern on device.** Trial randomness depends on local node
ids (they seed the min-hash clustering), so host- and device-routed runs are
bit-identical only if both assign ids in the same per-shard order.  Keeping
the gid -> local-id map in device memory (a :mod:`~repro.core.engine.hashtable`
open-addressing table per shard) gives both paths one source of truth and
makes the host path a true differential reference for the router.

SPMD hazard audit (docs/KNOWN_ISSUES.md): all gather/scatter here happens
*inside* ``shard_map`` on per-device local arrays, so the GSPMD
concat-of-aligned-slices pattern that miscompiled ``apply_rope`` cannot
arise — the partitioner never sees these concatenations.
"""
from __future__ import annotations

from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P

from repro.core.engine.hashtable import HashTable, ht_find, ht_new, ht_set
from repro.core.engine.state import EngineConfig, new_state
from repro.core.engine.trial import step_fn

INVALID = jnp.int32(-1)


# --------------------------------------------------------------------------- #
# device-resident gid -> local-nid interning
# --------------------------------------------------------------------------- #


class InternState(NamedTuple):
    """Per-shard device-resident node intern table.

    Maps global ids (gids, assigned by the host in label-encounter order) to
    the shard's dense local id space ``[0, n_cap)`` that the engine state
    arrays are indexed by.  ``l2g`` is the reverse map used by
    ``materialize``/``live_edges`` to translate summaries back to caller
    labels, so delivery order (which fixes nid assignment) is fully
    recoverable on the host.
    """

    g2l: HashTable      # (gid, 0) -> local nid
    l2g: jax.Array      # int32[n_cap]: local nid -> gid (-1 unset)
    n_nodes: jax.Array  # int32: next fresh nid == number interned
    n_dropped: jax.Array  # int32: endpoint interns dropped at full capacity


def intern_new(cfg: EngineConfig) -> InternState:
    cap = 1
    while cap < 4 * cfg.n_cap:   # ~25% max load keeps probes O(1)
        cap <<= 1
    return InternState(
        g2l=ht_new(cap),
        l2g=jnp.full((cfg.n_cap,), -1, jnp.int32),
        n_nodes=jnp.int32(0),
        n_dropped=jnp.int32(0),
    )


def _intern_one(ist: InternState, gid: jax.Array, valid: jax.Array,
                n_cap: int) -> Tuple[InternState, jax.Array]:
    """Dense first-come-first-served nid for gid; -1 when invalid/dropped."""
    g = jnp.where(valid, gid, 0)
    slot, found = ht_find(ist.g2l, g, 0)
    existing = ist.g2l.val[slot]
    fresh = valid & ~found
    room = ist.n_nodes < n_cap
    take = fresh & room
    nid_new = ist.n_nodes

    def ins(i: InternState) -> InternState:
        return i._replace(
            g2l=ht_set(i.g2l, g, 0, nid_new),
            l2g=i.l2g.at[nid_new].set(g),
            n_nodes=i.n_nodes + 1)

    ist = jax.lax.cond(take, ins, lambda i: i, ist)
    ist = ist._replace(
        n_dropped=ist.n_dropped + (fresh & ~room).astype(jnp.int32))
    nid = jnp.where(found, existing, jnp.where(take, nid_new, INVALID))
    return ist, jnp.where(valid, nid, INVALID)


def intern_changes(ist: InternState, gu: jax.Array, gv: jax.Array,
                   n_cap: int) -> Tuple[InternState, jax.Array, jax.Array]:
    """Intern a change sequence in order: ``(ist, u_nid, v_nid)``.

    A change with a dropped endpoint (shard node capacity hit) maps to
    ``(-1, -1)`` — the engine skips it and ``n_dropped`` records the event
    for the host to surface.
    """

    def body(ist, ch):
        gu_i, gv_i = ch
        valid = (gu_i >= 0) & (gv_i >= 0)
        ist, nu = _intern_one(ist, gu_i, valid, n_cap)
        ist, nv = _intern_one(ist, gv_i, valid, n_cap)
        ok = (nu >= 0) & (nv >= 0)
        return ist, (jnp.where(ok, nu, INVALID), jnp.where(ok, nv, INVALID))

    ist, (u, v) = jax.lax.scan(body, ist, (gu, gv))
    return ist, u, v


# --------------------------------------------------------------------------- #
# host-routed (bucketed) step — the differential reference + overflow path
# --------------------------------------------------------------------------- #


def _state_specs(cfg: EngineConfig, axis: str):
    est_sds = jax.eval_shape(lambda: new_state(cfg))
    ist_sds = jax.eval_shape(lambda: intern_new(cfg))
    return (jax.tree.map(lambda _: P(axis), est_sds),
            jax.tree.map(lambda _: P(axis), ist_sds))


def make_bucketed_step(cfg: EngineConfig, mesh):
    """jit(shard_map) step consuming host-bucketed ``[n_shards, batch]`` gid
    rounds.  Bucketing/packing happens on the host; interning and the engine
    step run on device (``lax.map`` lays multiple shard replicas per device,
    keeping the engine's control flow intact instead of paying vmap's
    both-branches cost)."""
    axis = mesh.axis_names[0]
    est_specs, ist_specs = _state_specs(cfg, axis)

    def one(args):
        est, ist, gu, gv, ins = args
        ist, u, v = intern_changes(ist, gu, gv, cfg.n_cap)
        return step_fn(est, u, v, ins != 0, cfg), ist

    def local(est, ist, gu, gv, ins):
        return jax.lax.map(one, (est, ist, gu, gv, ins))

    return jax.jit(shard_map(
        local, mesh=mesh,
        in_specs=(est_specs, ist_specs, P(axis), P(axis), P(axis)),
        out_specs=(est_specs, ist_specs), check_rep=False))


# --------------------------------------------------------------------------- #
# device-routed step — shard keys, all_to_all exchange, engine rounds
# --------------------------------------------------------------------------- #


def make_routed_step(cfg: EngineConfig, mesh, n_shards: int, chunk: int,
                     lane_cap: int):
    """Compile the device-resident router for a fixed geometry.

    Returns a jitted ``(est, ist, gu, gv, ins) -> (est, ist, first)`` where
    the inputs are the stacked per-shard states plus flat ``[chunk]``
    gid-encoded change arrays (``-1`` padded) and ``first`` is, per device,
    the first stream position NOT routed because its (source, shard) lane
    overflowed ``lane_cap`` — ``chunk`` when everything was delivered.
    """
    axis = mesh.axis_names[0]
    n_dev = int(mesh.devices.size)
    n_loc = n_shards // n_dev
    if chunk % n_dev != 0:
        raise ValueError(f"chunk={chunk} must be divisible by n_dev={n_dev}")
    n_in = chunk // n_dev        # stream positions per source device
    lane_cap = min(lane_cap, n_in)   # a lane can't exceed its source slice
    r_cap = n_dev * lane_cap     # max deliverable per shard per chunk
    b = cfg.batch
    est_specs, ist_specs = _state_specs(cfg, axis)

    def local(est, ist, gu, gv, ins):
        # est/ist stacked [n_loc, ...]; gu/gv/ins local [n_in]
        me = jax.lax.axis_index(axis)
        valid = (gu >= 0) & (gv >= 0)
        dest = jnp.where(valid, jnp.minimum(gu, gv) % n_shards, n_shards)

        # rank of each change within its (source, dest) lane; order-stable
        onehot = dest[:, None] == jnp.arange(n_shards, dtype=jnp.int32)[None]
        cum = jnp.cumsum(onehot.astype(jnp.int32), axis=0)
        rank = jnp.take_along_axis(
            cum, jnp.clip(dest, 0, n_shards - 1)[:, None], axis=1)[:, 0] - 1

        # capacity bound: route only the stream prefix before the first
        # overflowing position so the caller can replay the suffix in order
        pos = me * n_in + jnp.arange(n_in, dtype=jnp.int32)
        over = valid & (rank >= lane_cap)
        my_first = jnp.min(jnp.where(over, pos, jnp.int32(chunk)))
        first = jax.lax.pmin(my_first, axis)
        keep = valid & (rank < lane_cap) & (pos < first)

        # scatter kept changes into the [n_dev, n_loc, lane_cap] send lanes
        dd = jnp.where(keep, dest // n_loc, n_dev)   # OOB index -> dropped
        dl = jnp.where(keep, dest % n_loc, 0)
        rk = jnp.where(keep, rank, 0)
        payload = jnp.stack([gu, gv, ins.astype(jnp.int32)], axis=-1)
        send = jnp.full((n_dev, n_loc, lane_cap, 3), -1, jnp.int32)
        send = send.at[dd, dl, rk].set(payload, mode="drop")

        # exchange: recv[j, l] = source j's lane for my local shard l
        recv = jax.lax.all_to_all(send, axis, split_axis=0, concat_axis=0,
                                  tiled=True)
        # source-major flatten per shard == global stream order
        recv = jnp.swapaxes(recv, 0, 1).reshape(n_loc, r_cap, 3)
        rgu, rgv, rins = recv[..., 0], recv[..., 1], recv[..., 2]

        # stable compaction of each shard's bucket to the front
        rvalid = rgu >= 0
        cpos = jnp.cumsum(rvalid.astype(jnp.int32), axis=1) - 1
        idx = jnp.where(rvalid, cpos, r_cap)
        rows = jnp.arange(n_loc, dtype=jnp.int32)[:, None]
        pad_row = jnp.full((n_loc, r_cap), -1, jnp.int32)
        cgu = pad_row.at[rows, idx].set(rgu, mode="drop")
        cgv = pad_row.at[rows, idx].set(rgv, mode="drop")
        cins = jnp.zeros((n_loc, r_cap), jnp.int32).at[rows, idx].set(
            rins, mode="drop")
        counts = rvalid.sum(axis=1).astype(jnp.int32)

        # intern each shard's whole bucket up front — the same order host
        # bucketing interns in, so both paths assign identical local ids
        def int_one(args):
            ist_l, gu_l, gv_l = args
            return intern_changes(ist_l, gu_l, gv_l, cfg.n_cap)

        ist, u_all, v_all = jax.lax.map(int_one, (ist, cgu, cgv))

        # one spare round of padding so dynamic_slice never clamps
        u_all = jnp.concatenate(
            [u_all, jnp.full((n_loc, b), -1, jnp.int32)], axis=1)
        v_all = jnp.concatenate(
            [v_all, jnp.full((n_loc, b), -1, jnp.int32)], axis=1)
        i_all = jnp.concatenate(
            [cins, jnp.zeros((n_loc, b), jnp.int32)], axis=1)

        # every shard steps the same number of rounds (uniform PRNG advance,
        # matching the host path's ceil(max_bucket / batch) schedule)
        rounds = jax.lax.pmax(jnp.max((counts + b - 1) // b), axis)

        def round_body(carry):
            r, est = carry

            def one(args):
                est_l, u_l, v_l, i_l = args
                us = jax.lax.dynamic_slice(u_l, (r * b,), (b,))
                vs = jax.lax.dynamic_slice(v_l, (r * b,), (b,))
                fs = jax.lax.dynamic_slice(i_l, (r * b,), (b,)) != 0
                return step_fn(est_l, us, vs, fs, cfg)

            return r + 1, jax.lax.map(one, (est, u_all, v_all, i_all))

        _, est = jax.lax.while_loop(
            lambda c: c[0] < rounds, round_body, (jnp.int32(0), est))
        return est, ist, first[None]

    return jax.jit(shard_map(
        local, mesh=mesh,
        in_specs=(est_specs, ist_specs, P(axis), P(axis), P(axis)),
        out_specs=(est_specs, ist_specs, P(axis)), check_rep=False))


def default_lane_cap(chunk: int, n_dev: int, n_shards: int,
                     batch: int) -> int:
    """4x-headroom lane size over the balanced expectation, floored at one
    engine batch and capped at the source slice (beyond which a lane cannot
    fill) — overflows then only occur under heavy key skew."""
    balanced = -(-chunk // (n_dev * n_shards))   # ceil
    return min(max(batch, 4 * balanced), chunk // n_dev)
