"""Mesh construction + NamedSharding resolution from logical-axis rules.

The declarative per-leaf rules live in :mod:`repro.configs.base`
(``LM_LOGICAL_RULES`` et al.); this module resolves them against a concrete
mesh into ``PartitionSpec`` / ``NamedSharding`` trees, guarding every
placement for divisibility so one rule set serves the 512-chip production
meshes and the 8-fake-device host tests alike.  It also provides the
``shard_map``-based data-parallel wrapper used by batch-sharded pipelines.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Sequence, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# The rule tables are DECLARED in repro.configs.base but must be loaded
# lazily: model modules import repro.dist, and repro.configs imports the
# model modules — an eager import here would re-enter a partially
# initialized repro.models.* depending on which side is imported first.
_RULE_EXPORTS = {
    "LM_RULES": "LM_LOGICAL_RULES",
    "GNN_RULES": "GNN_LOGICAL_RULES",
    "RECSYS_RULES": "RECSYS_LOGICAL_RULES",
    "LOGICAL_TO_MESH": "LOGICAL_TO_MESH",
    "MOE_FFN_LOGICAL_RULES": "MOE_FFN_LOGICAL_RULES",
}


def __getattr__(name):  # PEP 562: resolve rule tables on first access
    if name in _RULE_EXPORTS:
        from repro.configs import base as _config_base
        return getattr(_config_base, _RULE_EXPORTS[name])
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")


# ----------------------------------------------------------------- mesh utils


def batch_axes(mesh: Mesh) -> Tuple[str, ...]:
    """Mesh axes that carry the batch (data-parallel) dimension."""
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def batch_spec(mesh: Mesh, rank: int) -> P:
    """P sharding dim 0 over the data axes, replicating the rest."""
    ax = batch_axes(mesh)
    lead = ax if len(ax) > 1 else (ax[0] if ax else None)
    return P(lead, *([None] * (rank - 1)))


def _entry_axes(entry) -> Tuple[str, ...]:
    if entry is None:
        return ()
    if isinstance(entry, str):
        return (entry,)
    return tuple(a for a in entry if a is not None)


def guard_spec(spec: P, shape: Sequence[int], mesh: Mesh) -> P:
    """Drop per-dim placements that are absent from the mesh, already used on
    an earlier dim, or do not divide the dim — GSPMD-safe by construction."""
    entries = list(spec) + [None] * (len(shape) - len(spec))
    used: set = set()
    out = []
    for entry, dim in zip(entries, shape):
        axes = tuple(a for a in _entry_axes(entry)
                     if a in mesh.axis_names and a not in used)
        size = 1
        for a in axes:
            size *= mesh.shape[a]
        if not axes or size <= 1 or dim % size != 0:
            out.append(None)
        else:
            used.update(axes)
            out.append(axes if len(axes) > 1 else axes[0])
    return P(*out)


def input_sharding(mesh: Mesh, shape: Sequence[int], spec: P) -> NamedSharding:
    """NamedSharding for an input of the given shape, divisibility-guarded."""
    return NamedSharding(mesh, guard_spec(spec, shape, mesh))


# ------------------------------------------------------------ rule resolution


def _leaf_name(path) -> Optional[str]:
    """Last string key on a tree path (skipping list/tuple indices)."""
    for entry in reversed(path):
        name = getattr(entry, "key", None)
        if isinstance(name, str):
            return name
        name = getattr(entry, "name", None)
        if isinstance(name, str):
            return name
    return None


def _logical_to_entry(logical: Optional[str],
                      fsdp_axes: Tuple[str, ...]) -> Optional[Tuple[str, ...]]:
    from repro.configs.base import LOGICAL_TO_MESH
    mapped = LOGICAL_TO_MESH.get(logical)
    if mapped == "__fsdp__":
        mapped = tuple(fsdp_axes)
    return mapped


def spec_for_leaf(name: Optional[str], shape: Sequence[int], mesh: Mesh,
                  rules: Dict[str, tuple],
                  fsdp_axes: Tuple[str, ...] = ("data",),
                  is_moe: bool = False) -> P:
    """Resolve one leaf's logical rule to a guarded PartitionSpec."""
    from repro.configs.base import MOE_FFN_LOGICAL_RULES
    rule = None
    if name is not None:
        if is_moe and name in MOE_FFN_LOGICAL_RULES and \
                len(shape) >= len(MOE_FFN_LOGICAL_RULES[name]):
            rule = MOE_FFN_LOGICAL_RULES[name]
        else:
            rule = rules.get(name)
    if rule is None:
        return P()
    # rules address the TRAILING dims; leading (layer-stack/expert) dims
    # replicate unless the rule names them explicitly.  A leaf with FEWER
    # dims than its rule (a squeezed/bias variant sharing the name) keeps
    # only the rule's trailing entries, preserving the alignment contract.
    rule = rule[-len(shape):] if shape else ()
    lead = [None] * (len(shape) - len(rule))
    entries = lead + [_logical_to_entry(l, tuple(fsdp_axes)) for l in rule]
    return guard_spec(P(*entries), shape, mesh)


def tree_specs(tree: Any, rules: Dict[str, tuple], mesh: Mesh, *,
               fsdp_axes: Tuple[str, ...] = ("data",),
               is_moe: bool = False) -> Any:
    """PartitionSpec tree for a parameter tree under the given logical rules."""
    def one(path, leaf):
        shape = getattr(leaf, "shape", ())
        return spec_for_leaf(_leaf_name(path), shape, mesh, rules,
                             fsdp_axes=fsdp_axes, is_moe=is_moe)
    return jax.tree_util.tree_map_with_path(one, tree)


def tree_shardings(tree: Any, rules: Dict[str, tuple], mesh: Mesh, *,
                   fsdp_axes: Tuple[str, ...] = ("data",),
                   is_moe: bool = False) -> Any:
    """NamedSharding tree (device-placeable form of ``tree_specs``)."""
    specs = tree_specs(tree, rules, mesh, fsdp_axes=fsdp_axes, is_moe=is_moe)
    return jax.tree.map(lambda s: NamedSharding(mesh, s), specs,
                        is_leaf=lambda x: isinstance(x, P))


# -------------------------------------------------------- data-parallel wrap


def data_parallel(fn, mesh: Mesh):
    """``shard_map`` wrapper splitting every arg/output's leading dim over the
    mesh's batch axes (all axes if the mesh has no data axis).

    ``fn`` must be shardwise-independent: no cross-batch reductions, each
    output carries the global batch on dim 0.
    """
    from jax.experimental.shard_map import shard_map

    ax = batch_axes(mesh) or tuple(mesh.axis_names)
    spec = P(ax if len(ax) > 1 else ax[0])
    # keyed on (treedef, leaf avals): grows like a jit cache, one entry per
    # distinct input structure/shape set
    cache: Dict[Any, Any] = {}

    def wrapped(*args):
        key = (jax.tree.structure(args),
               tuple((l.shape, str(l.dtype))
                     for l in jax.tree.leaves(args)))
        sm = cache.get(key)
        if sm is None:
            out_sds = jax.eval_shape(fn, *args)
            in_specs = jax.tree.map(lambda _: spec, args)
            out_specs = jax.tree.map(lambda _: spec, out_sds)
            sm = jax.jit(shard_map(fn, mesh=mesh, in_specs=in_specs,
                                   out_specs=out_specs, check_rep=False))
            cache[key] = sm
        return sm(*args)

    return wrapped
