"""Distribution layer: annotation, sharding rules, collectives, routing.

``annotate`` must import before ``sharding``: resolving the rule tables pulls
in :mod:`repro.configs`, whose arch modules import the model code, which in
turn imports ``repro.dist.annotate`` — keeping annotate first makes that
cycle re-entrant-safe.

``router`` resolves lazily (PEP 562, like the rule tables in ``sharding``)
for two reasons: it imports the engine package, so an eager import here
would re-enter :mod:`repro.core.engine` half-initialized whenever the
engine side is imported first; and the engine's module-level jnp constants
initialize the JAX backend, which would break this package's guarantee of
touching no jax device state at import time (model modules import
``repro.dist`` at import time, often before the caller sets
``XLA_FLAGS``).
"""
from repro.dist import annotate          # noqa: F401  (import order matters)
from repro.dist import collectives       # noqa: F401
from repro.dist import sharding          # noqa: F401


def __getattr__(name):  # PEP 562: keep `import repro.dist` device-state-free
    if name == "router":
        # NOT `from repro.dist import router` — the fromlist resolver calls
        # back into this __getattr__ and recurses
        import importlib
        return importlib.import_module("repro.dist.router")
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")


__all__ = ["annotate", "collectives", "sharding", "router"]
