"""Distribution layer: logical-axis annotation, sharding rules, collectives.

``annotate`` must import before ``sharding``: resolving the rule tables pulls
in :mod:`repro.configs`, whose arch modules import the model code, which in
turn imports ``repro.dist.annotate`` — keeping annotate first makes that
cycle re-entrant-safe.
"""
from repro.dist import annotate          # noqa: F401  (import order matters)
from repro.dist import collectives       # noqa: F401
from repro.dist import sharding          # noqa: F401

__all__ = ["annotate", "collectives", "sharding"]
