"""Compressed cross-shard collectives.

Gradient/statistic all-reduces dominate the interconnect budget at pod
scale.  ``compressed_psum`` applies symmetric int8 quantization to the local
contribution before the reduction: the error model of a quantized all-reduce
(at most half a quantization step per shard, so the relative error of the
sum stays small for well-scaled inputs).  NOTE on wire size: the psum itself
still runs on the dequantized f32 tensor — XLA offers no int8 all-reduce —
so this establishes the ACCURACY contract of compression; actual 4x wire
savings need a backend collective that moves the (q, scale) payload.
"""
from __future__ import annotations

from typing import Tuple, Union

import jax
import jax.numpy as jnp

AxisName = Union[str, Tuple[str, ...]]


def int8_quantize(x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Symmetric per-tensor int8 quantization: returns (q, scale).

    ``dequantize(q, scale)`` is within ``scale / 2`` of ``x`` elementwise.
    """
    amax = jnp.max(jnp.abs(x.astype(jnp.float32)))
    scale = jnp.maximum(amax / 127.0, jnp.float32(1e-12))
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale), -127, 127)
    return q.astype(jnp.int8), scale


def int8_dequantize(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def compressed_psum(x: jax.Array, axis_name: AxisName) -> jax.Array:
    """psum over ``axis_name`` with int8-compressed local contributions."""
    q, scale = int8_quantize(x)
    return jax.lax.psum(int8_dequantize(q, scale), axis_name)


def compressed_pmean(x: jax.Array, axis_name: AxisName) -> jax.Array:
    n = jax.lax.psum(jnp.ones((), jnp.float32), axis_name)
    return compressed_psum(x, axis_name) / n
