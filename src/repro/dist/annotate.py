"""Logical-axis annotation helpers for model code.

Model forward passes declare *where an activation wants to live* without
naming a concrete mesh::

    h = annotate.constrain(h, annotate.data_axes(), "model", None)

The mesh is installed by the launcher (``set_mesh``); with no mesh installed
every helper is an exact no-op, so single-device tests, CPU smoke runs and
``jax.eval_shape`` dry-runs never touch device state.  Each per-dim entry may
be ``None`` (replicated), a mesh axis name, or a tuple of axis names; entries
that reference axes absent from the installed mesh, or that do not divide the
dimension, are dropped rather than erroring — the constraint is a placement
hint, not a shape contract.
"""
from __future__ import annotations

from typing import Optional, Tuple, Union

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

AxisSpec = Union[None, str, Tuple[str, ...]]

_MESH: Optional[Mesh] = None


def set_mesh(mesh: Optional[Mesh]) -> None:
    """Install the mesh consumed by subsequent ``constrain`` calls."""
    global _MESH
    _MESH = mesh


def get_mesh() -> Optional[Mesh]:
    return _MESH


def clear_mesh() -> None:
    set_mesh(None)


def data_axes() -> Tuple[str, ...]:
    """Mesh axes carrying data parallelism (empty without a mesh)."""
    if _MESH is None:
        return ()
    from repro.dist.sharding import batch_axes
    return batch_axes(_MESH)


def model_axes() -> Tuple[str, ...]:
    if _MESH is None:
        return ()
    return tuple(a for a in ("model",) if a in _MESH.axis_names)


def constrain(x: jax.Array, *axes: AxisSpec) -> jax.Array:
    """``with_sharding_constraint`` against the installed mesh (or identity).

    One ``AxisSpec`` per array dim; invalid placements degrade to replicated
    per-dim (via the shared ``sharding.guard_spec``) instead of failing, so
    model code stays mesh-shape agnostic.
    """
    if _MESH is None:
        return x
    if len(axes) != x.ndim:
        raise ValueError(
            f"constrain got {len(axes)} axis specs for rank-{x.ndim} array")
    from repro.dist.sharding import guard_spec
    spec = guard_spec(P(*axes), x.shape, _MESH)
    if all(e is None for e in spec):
        return x
    return jax.lax.with_sharding_constraint(x, NamedSharding(_MESH, spec))


def constrain_batch(x: jax.Array) -> jax.Array:
    """Shard the leading (batch) dim over the data axes; identity otherwise."""
    if _MESH is None or x.ndim == 0:
        return x
    return constrain(x, data_axes(), *([None] * (x.ndim - 1)))
