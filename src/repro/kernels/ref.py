"""Pure-jnp oracles for every Pallas kernel (the `ref.py` contract).

These are the semantics of record: kernels must `allclose` against them in
interpret mode across the shape/dtype sweeps in tests/test_kernels.py, and
they are also the default execution path on non-TPU backends (so the whole
framework runs and lowers on CPU).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp


def segment_reduce_ref(senders: jax.Array, receivers: jax.Array,
                       x: jax.Array, n_out: int, reduce: str = "sum",
                       ) -> jax.Array:
    """out[r] = reduce over edges e with receivers[e]==r of x[senders[e]]."""
    msgs = x[senders]
    if reduce == "sum":
        return jax.ops.segment_sum(msgs, receivers, num_segments=n_out)
    if reduce == "min":
        out = jax.ops.segment_min(msgs, receivers, num_segments=n_out)
    elif reduce == "max":
        out = jax.ops.segment_max(msgs, receivers, num_segments=n_out)
    else:
        raise ValueError(reduce)
    # zero EMPTY segments only — masking on isfinite would also clobber
    # legitimate ±inf inputs that survive a nonempty min/max
    cnt = jax.ops.segment_sum(jnp.ones_like(receivers, dtype=jnp.int32),
                              receivers, num_segments=n_out)
    mask = jnp.reshape(cnt > 0, (n_out,) + (1,) * (out.ndim - 1))
    return jnp.where(mask, out, 0.0)


def ht_probe_ref(tk1: jax.Array, tk2: jax.Array, tval: jax.Array,
                 q1: jax.Array, q2: jax.Array, *, prehashed: bool = False,
                 mode: str = "find"):
    """Batched-probe oracle: vmap over the scalar ``hashtable.py`` loops.

    The semantics of record for ``kernels/ht_probe.py`` — and exactly the
    XLA lowering the engine compiles under ``REPRO_TRIAL_BACKEND=xla``, so
    the kernel-vs-ref differential is also a kernel-vs-production-path
    differential.  Returns ``(slot, found, val)`` with ``val`` read at the
    key's chain end (pass-1 slot) whether or not the key was found.
    """
    from repro.core.engine.hashtable import (HashTable, _find_insert_slot,
                                             ht_find)
    ht = HashTable(k1=tk1, k2=tk2, val=tval)
    q1 = jnp.asarray(q1, jnp.int32)
    q2 = jnp.asarray(q2, jnp.int32)
    if mode == "find":
        slot, found = jax.vmap(
            lambda a, b: ht_find(ht, a, b, prehashed=prehashed))(q1, q2)
        return slot, found, tval[slot]
    if mode != "insert":
        raise ValueError(f"mode must be 'find' or 'insert': {mode}")
    slot, found = jax.vmap(
        lambda a, b: _find_insert_slot(ht, a, b, prehashed=prehashed))(q1, q2)
    # the value still reads at the FIND chain end (insert slots may be
    # TOMB resurrections whose stale val must not leak)
    fslot, _ = jax.vmap(
        lambda a, b: ht_find(ht, a, b, prehashed=prehashed))(q1, q2)
    return slot, found, tval[fslot]


def summary_spmm_ref(x: jax.Array, n2s: jax.Array, n_super: int,
                     p_src: jax.Array, p_dst: jax.Array,
                     cp_src: jax.Array, cp_dst: jax.Array,
                     cm_src: jax.Array, cm_dst: jax.Array,
                     self_loop_super: jax.Array) -> jax.Array:
    """Neighborhood aggregation  Y = A @ X  *from the summary representation*.

    A is never materialized:  Y[u] = sum over superedges {S_u, B} of
    sum_{v in B} X[v]  (+ intra-supernode clique when (S_u,S_u) in P,
    excluding u itself)  + C+ contributions - C- contributions.

    Arguments are directed edge lists: superedges appear in both directions
    in (p_src, p_dst) except self-pairs, which are flagged per-supernode in
    ``self_loop_super`` (bool[n_super]).  C+/C- node pairs appear in both
    directions.
    """
    z = jax.ops.segment_sum(x, n2s, num_segments=n_super)     # supernode sums
    w = jax.ops.segment_sum(z[p_src], p_dst, num_segments=n_super)
    y = w[n2s]
    # self superedge (A,A): u gets (Z[A] - X[u])
    self_mask = self_loop_super[n2s][:, None]
    y = y + jnp.where(self_mask, z[n2s] - x, 0.0)
    y = y + jax.ops.segment_sum(x[cp_src], cp_dst, num_segments=x.shape[0])
    y = y - jax.ops.segment_sum(x[cm_src], cm_dst, num_segments=x.shape[0])
    return y


def dense_spmm_ref(senders: jax.Array, receivers: jax.Array, x: jax.Array,
                   ) -> jax.Array:
    """Plain edge-list A @ X (oracle for summary_spmm equivalence tests)."""
    return jax.ops.segment_sum(x[senders], receivers, num_segments=x.shape[0])


def embedding_bag_ref(table: jax.Array, indices: jax.Array,
                      offsets: jax.Array, mode: str = "sum") -> jax.Array:
    """torch.nn.EmbeddingBag semantics with jnp.take + segment_sum.

    indices: int32[nnz] flat lookup ids; offsets: int32[B+1] bag boundaries.
    """
    b = offsets.shape[0] - 1
    bag_ids = jnp.searchsorted(offsets, jnp.arange(indices.shape[0]),
                               side="right") - 1
    rows = jnp.take(table, indices, axis=0)
    summed = jax.ops.segment_sum(rows, bag_ids, num_segments=b)
    if mode == "sum":
        return summed
    counts = jnp.maximum(offsets[1:] - offsets[:-1], 1)
    return summed / counts[:, None].astype(summed.dtype)


def flash_attention_ref(q: jax.Array, k: jax.Array, v: jax.Array,
                        causal: bool = True,
                        bias: Optional[jax.Array] = None,
                        q_chunk: int = 1024) -> jax.Array:
    """Reference multi-head attention. q: [B,H,Tq,D], k/v: [B,Hkv,Tk,D].

    Long query lengths are processed in chunks (scan over q blocks) so the
    [Tq, Tk] score matrix is never fully materialized — this keeps the 32k
    prefill cells lowerable on any backend and bounds activation memory in
    the dry-run's memory_analysis.
    """
    b, h, tq, d = q.shape
    hkv = k.shape[1]
    if hkv != h:  # GQA: broadcast kv heads over query groups
        rep = h // hkv
        k = jnp.repeat(k, rep, axis=1)
        v = jnp.repeat(v, rep, axis=1)
    tk = k.shape[2]

    def block(qb, qpos):
        scores = jnp.einsum("bhqd,bhkd->bhqk", qb, k) / jnp.sqrt(d).astype(q.dtype)
        scores = scores.astype(jnp.float32)
        if bias is not None:
            scores = scores + bias
        if causal:
            mask = qpos[:, None] + (tk - tq) >= jnp.arange(tk)[None, :]
            scores = jnp.where(mask, scores, -1e30)
        probs = jax.nn.softmax(scores, axis=-1)
        return jnp.einsum("bhqk,bhkd->bhqd", probs.astype(v.dtype), v)

    if tq <= q_chunk or tq % q_chunk:
        return block(q, jnp.arange(tq))

    n_chunks = tq // q_chunk
    qr = q.reshape(b, h, n_chunks, q_chunk, d).transpose(2, 0, 1, 3, 4)

    _, out = jax.lax.scan(
        lambda c, i: ((), block(qr[i], i * q_chunk + jnp.arange(q_chunk))),
        (), jnp.arange(n_chunks))
    dv = v.shape[-1]   # MLA attends over the latent: d_v != d_q
    return out.transpose(1, 2, 0, 3, 4).reshape(b, h, tq, dv)


def minhash_signature_ref(senders: jax.Array, receivers: jax.Array,
                          n_nodes: int, seed: int = 0) -> jax.Array:
    """Min-hash cluster signature per node: min over neighbors of hash(nbr)."""
    h = _mixhash(senders.astype(jnp.uint32), jnp.uint32(seed)).astype(jnp.float32)
    out = jax.ops.segment_min(h, receivers, num_segments=n_nodes)
    return jnp.where(jnp.isfinite(out), out, jnp.float32(2**31 - 1)).astype(jnp.int32)


def _mixhash(x: jax.Array, seed: jax.Array) -> jax.Array:
    h = x * jnp.uint32(0x9E3779B9) + seed
    h = (h ^ (h >> 16)) * jnp.uint32(0x85EBCA6B)
    h = h ^ (h >> 13)
    return h & jnp.uint32(0x7FFFFFFE)
