"""Pallas kernel: batched open-addressing hash-table probes (trial hot loop).

MoSSo's per-change budget is dominated by hash-table probe chains: every
trial phase (TP sampling, neighbor slots, the closed-form dphi's E_AX /
E_BX lookups) and the router's intern pre-lookup issue *batches* of
independent probes against one table, and the XLA lowering
(`core/engine/hashtable.ht_find` under `jax.vmap`) dispatches each batch
as a batched `lax.while_loop` — on CPU that pays the measured fixed
dispatch tax per loop (docs/KNOWN_ISSUES.md), and on accelerators it
round-trips HBM per probe step.

This kernel fuses one whole probe batch into a single launch:

* the table arrays (``k1``/``k2``/``val``, ``int32[cap]``) are resident
  for the duration of the launch (VMEM on TPU — capacities are sized in
  the tens of KBs; the compiler places ``pl.ANY`` operands),
* each program instance owns a *block of lanes* (one probe chain per
  lane, the vmapped-replica layout's native shape),
* all lanes advance through ONE uniform ``lax.while_loop`` — per-lane
  state is a (frozen-when-done) probe offset, so there is no per-lane
  control flow, exactly the predication style of the trial engine, and
* results are committed as masked slot writes: a lane's output freezes
  the step its chain terminates, and padding lanes (the ``ok=False``
  contract: masked callers may feed garbage keys) probe like any other
  lane — chains always terminate (EMPTY or full wrap after ``cap``
  steps) and the caller ignores their slots.

**Bitwise contract.**  For identical inputs the kernel must produce
slot/found/value triples *bitwise identical* to the while-loop lowering
(`kernels/ref.ht_probe_ref`, which wraps the `hashtable.py` loops) — the
probe sequence IS the on-device table layout, so "close" is meaningless.
`tests/test_kernels.py` sweeps capacities, load factors, tombstone
densities, garbage keys, and full-chain wrap-arounds in interpret mode.

`mode="find"` reproduces :func:`~repro.core.engine.hashtable.ht_find`
(stop at key or EMPTY); `mode="insert"` reproduces
:func:`~repro.core.engine.hashtable._find_insert_slot` (the upsert
two-pass: the key's slot if present, else the first EMPTY/TOMB slot).
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.engine.hashtable import EMPTY, TOMB, _probe_start

# sentinel keys as python ints: jnp scalars would be captured as kernel
# constants, which pallas_call rejects
_EMPTY = int(EMPTY)
_TOMB = int(TOMB)

# lanes per program instance: one VPU lane row on TPU; small batches pad
# up to one block, large batches tile the grid.
DEFAULT_BLOCK = 128


def _probe_kernel(k1_ref, k2_ref, val_ref, q1_ref, q2_ref,
                  slot_ref, found_ref, val_out_ref, *,
                  cap: int, mode: str, prehashed: bool):
    """One block of probe chains, advanced by a single uniform while loop.

    Loop semantics mirror the scalar ``hashtable.py`` loops exactly: a
    lane's offset ``i`` advances while the scalar loop's condition holds
    and freezes forever once it fails (masked update — the lane's "done"
    bit is the predicate), so the final per-lane offset is the first
    ``i`` where the scalar loop would have stopped.  The loop itself
    runs until every lane froze: max-chain-length trips, no per-lane
    control flow.
    """
    tk1 = k1_ref[...]          # int32[cap], launch-resident
    tk2 = k2_ref[...]
    tv = val_ref[...]
    q1 = q1_ref[...]           # int32[1, bl]
    q2 = q2_ref[...]
    start = _probe_start(q1, q2, cap, prehashed)

    def chain(stop_fn):
        """First probe offset per lane where ``stop_fn(slot keys)`` holds
        (or the ``i == cap`` wrap bound is hit) — vectorized pass over the
        block, bit-equal to the scalar while loops."""

        def cond(c):
            return jnp.any(~c[1])

        def body(c):
            i, done = c
            slot = (start + i) & (cap - 1)
            stop = stop_fn(tk1[slot], tk2[slot]) | (i >= cap)
            done_now = done | stop
            return jnp.where(done_now, i, i + 1), done_now

        i0 = jnp.zeros_like(start)
        i, _ = jax.lax.while_loop(cond, body,
                                  (i0, jnp.zeros(i0.shape, bool)))
        return i

    # pass 1: the key's chain — stop at the key itself or at EMPTY
    i1 = chain(lambda k1s, k2s: ((k1s == q1) & (k2s == q2))
               | (k1s == _EMPTY))
    slot1 = (start + i1) & (cap - 1)
    found = (tk1[slot1] == q1) & (tk2[slot1] == q2)

    if mode == "find":
        slot = slot1
    else:
        # pass 2 (upsert): first free (EMPTY or TOMB) slot; only read
        # when the key was absent
        i2 = chain(lambda k1s, k2s: (k1s == _EMPTY) | (k1s == _TOMB))
        slot2 = (start + i2) & (cap - 1)
        slot = jnp.where(found, slot1, slot2)

    slot_ref[...] = slot
    found_ref[...] = found.astype(jnp.int32)
    val_out_ref[...] = tv[slot1]


def ht_probe_batch(tk1: jax.Array, tk2: jax.Array, tval: jax.Array,
                   q1: jax.Array, q2: jax.Array, *,
                   prehashed: bool = False, mode: str = "find",
                   block: int = DEFAULT_BLOCK, interpret: bool = False,
                   ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Probe a batch of keys against one table in a single kernel launch.

    Args: table arrays ``int32[cap]`` (``cap`` a power of two) and flat
    query words ``int32[B]``.  Returns ``(slot, found, val)`` with
    ``slot`` the find/upsert slot per lane, ``found`` a bool mask and
    ``val`` the value at the *key's* chain end (garbage when ``~found``
    — callers select with their own default, mirroring ``ht_lookup``).

    Padding lanes probe key ``(0, 0)`` and are sliced off; under
    ``jax.vmap`` (the stacked-replica layout) the batching rule adds a
    grid dimension, so all replicas' probes still form one launch.
    """
    if mode not in ("find", "insert"):
        raise ValueError(f"mode must be 'find' or 'insert': {mode}")
    cap = tk1.shape[0]
    assert cap & (cap - 1) == 0, "capacity must be a power of two"
    q1 = jnp.asarray(q1, jnp.int32)
    q2 = jnp.asarray(q2, jnp.int32)
    b = q1.shape[0]
    bl = min(block, max(8, b))
    nb = -(-b // bl)
    pad = nb * bl - b
    if pad:
        q1 = jnp.concatenate([q1, jnp.zeros((pad,), jnp.int32)])
        q2 = jnp.concatenate([q2, jnp.zeros((pad,), jnp.int32)])
    q1 = q1.reshape(nb, bl)
    q2 = q2.reshape(nb, bl)

    out_sds = jax.ShapeDtypeStruct((nb, bl), jnp.int32)
    slot, found, val = pl.pallas_call(
        functools.partial(_probe_kernel, cap=cap, mode=mode,
                          prehashed=prehashed),
        grid=(nb,),
        in_specs=[
            pl.BlockSpec(memory_space=pl.ANY),          # k1 (launch-resident)
            pl.BlockSpec(memory_space=pl.ANY),          # k2
            pl.BlockSpec(memory_space=pl.ANY),          # val
            pl.BlockSpec((1, bl), lambda i: (i, 0)),    # q1 lane block
            pl.BlockSpec((1, bl), lambda i: (i, 0)),    # q2 lane block
        ],
        out_specs=[pl.BlockSpec((1, bl), lambda i: (i, 0))] * 3,
        out_shape=[out_sds, out_sds, out_sds],
        interpret=interpret,
    )(tk1, tk2, tval, q1, q2)
    slot = slot.reshape(-1)[:b]
    found = found.reshape(-1)[:b] != 0
    val = val.reshape(-1)[:b]
    return slot, found, val
