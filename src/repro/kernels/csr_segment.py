"""Pallas TPU kernel: blocked CSR segment-reduce (the graph hot loop).

This is the bulk compute primitive behind (a) `summary_spmm` — neighborhood
aggregation directly on the summarized representation (the paper's
"Queryable" property as a compute kernel), (b) GNN message passing for the
assigned GNN architectures, and (c) the RecSys embedding-bag.

TPU adaptation (DESIGN.md): instead of GPU-style atomics/scatter, edges are
pre-sorted by destination row and the kernel walks one *row block* per grid
step, accumulating gathered source rows into a VMEM-resident output tile.
The TPU grid is sequential, so no cross-step races exist; the feature axis
is tiled to the 128-lane VPU/MXU width.

Layout:
  senders  int32[E_pad]   source node per edge (sorted by destination row)
  row_off  int32[NB + 1]  CSR offsets of each row *block* into senders
  dst_loc  int32[E_pad]   destination row within its block (0..BN-1)
  x        f32[N, F]      dense features (HBM; rows DMA'd on demand)
  out      f32[N, F]      segment-reduced output

`reduce` in {"sum", "min", "max"} (min/max power the min-hash signatures).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

_INIT = {"sum": 0.0, "min": float("inf"), "max": float("-inf")}


def _kernel(row_off_ref, senders_ref, dst_loc_ref, x_ref, out_ref, *,
            bn: int, bf: int, reduce: str, e_cap: int):
    ib = pl.program_id(0)      # row-block index
    # fj = pl.program_id(1)    # feature-block index (implicit via BlockSpec)
    start = row_off_ref[ib]
    stop = row_off_ref[ib + 1]

    acc0 = jnp.full((bn, bf), _INIT[reduce], dtype=jnp.float32)
    cnt0 = jnp.zeros((bn, 1), dtype=jnp.int32)

    def body(e, carry):
        acc, cnt = carry
        src = senders_ref[e]
        loc = dst_loc_ref[e]
        row = pl.load(x_ref, (pl.dslice(src, 1), slice(None)))  # [1, bf]
        onehot = (jax.lax.iota(jnp.int32, bn) == loc)[:, None]  # [bn, 1]
        cnt = cnt + onehot.astype(jnp.int32)
        if reduce == "sum":
            return acc + jnp.where(onehot, row, 0.0), cnt
        upd = jnp.where(onehot, row, _INIT[reduce])
        if reduce == "min":
            return jnp.minimum(acc, upd), cnt
        return jnp.maximum(acc, upd), cnt

    acc, cnt = jax.lax.fori_loop(start, stop, body, (acc0, cnt0))
    if reduce != "sum":
        # zero EMPTY rows only (rows with zero in-edges keep the ±inf
        # init); an isfinite mask would also clobber ±inf inputs, which
        # must flow through min/max exactly as segment_reduce_ref keeps
        # them
        acc = jnp.where(cnt > 0, acc, 0.0)
    out_ref[...] = acc.astype(out_ref.dtype)


def csr_segment_reduce(senders: jax.Array, row_off: jax.Array,
                       dst_loc: jax.Array, x: jax.Array, n_out: int,
                       *, bn: int = 128, bf: int = 128,
                       reduce: str = "sum", interpret: bool = False,
                       ) -> jax.Array:
    """Blocked segment-reduce: out[r] = reduce_{e: dst[e]==r} x[senders[e]].

    Callers prepare the blocked CSR layout with :func:`build_blocked_csr`.
    """
    n_pad = ((n_out + bn - 1) // bn) * bn
    f = x.shape[1]
    f_pad = ((f + bf - 1) // bf) * bf
    if f_pad != f:
        x = jnp.pad(x, ((0, 0), (0, f_pad - f)))
    nb = n_pad // bn

    out = pl.pallas_call(
        functools.partial(_kernel, bn=bn, bf=bf, reduce=reduce,
                          e_cap=senders.shape[0]),
        grid=(nb, f_pad // bf),
        in_specs=[
            pl.BlockSpec(memory_space=pl.ANY),             # row_off (small)
            pl.BlockSpec(memory_space=pl.ANY),             # senders
            pl.BlockSpec(memory_space=pl.ANY),             # dst_loc
            pl.BlockSpec((x.shape[0], bf), lambda i, j: (0, j)),  # x feature tile
        ],
        out_specs=pl.BlockSpec((bn, bf), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((n_pad, f_pad), x.dtype),
        interpret=interpret,
    )(row_off, senders, dst_loc, x)
    return out[:n_out, :f]


def build_blocked_csr(receivers, n_out: int, bn: int = 128):
    """Host/XLA-side layout pass: sort edges by destination row block.

    Returns (order, row_off, dst_loc): ``order`` permutes edge arrays into
    block order, ``row_off[i]`` is the first edge of row-block i and
    ``dst_loc`` the within-block destination row.
    """
    receivers = jnp.asarray(receivers, jnp.int32)
    order = jnp.argsort(receivers)
    sorted_r = receivers[order]
    nb = (n_out + bn - 1) // bn
    blk = sorted_r // bn
    row_off = jnp.searchsorted(blk, jnp.arange(nb + 1, dtype=jnp.int32)).astype(jnp.int32)
    dst_loc = (sorted_r % bn).astype(jnp.int32)
    return order, row_off, dst_loc
