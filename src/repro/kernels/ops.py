"""Jit'd public wrappers for the kernel layer.

Each op auto-selects: the Pallas kernel on TPU (or when forced via
``use_pallas=True``, which tests combine with ``interpret=True``), else the
pure-jnp reference path — so every model runs identically on CPU and lowers
cleanly in the multi-pod dry-run.
"""
from __future__ import annotations

from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels.csr_segment import build_blocked_csr, csr_segment_reduce
from repro.kernels.flash_attention import flash_attention as _flash_pallas
from repro.kernels.ht_probe import ht_probe_batch as _ht_probe_pallas


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def ht_probe(tk1: jax.Array, tk2: jax.Array, tval: jax.Array,
             q1: jax.Array, q2: jax.Array, *,
             prehashed: bool = False, mode: str = "find",
             use_pallas: Optional[bool] = None,
             interpret: Optional[bool] = None):
    """Batched open-addressing probe: ``(slot, found, val)`` per query.

    The engine's hot loop (``core/engine/hashtable.ht_find_batch`` /
    ``ht_lookup_batch`` dispatch here under ``REPRO_TRIAL_BACKEND=pallas``).
    Unlike the other ops this one defaults ``use_pallas`` to True — the
    caller has already chosen the kernel path — and instead auto-selects
    ``interpret``: compiled Pallas on TPU, interpret mode elsewhere (the
    kernel inlines into the XLA program, so the CPU-only CI can run the
    exact kernel data flow; XLA stays the only *compiled* CPU path).
    """
    if use_pallas is None:
        use_pallas = True
    if not use_pallas:
        return ref.ht_probe_ref(tk1, tk2, tval, q1, q2,
                                prehashed=prehashed, mode=mode)
    if interpret is None:
        interpret = not _on_tpu()
    return _ht_probe_pallas(tk1, tk2, tval, q1, q2, prehashed=prehashed,
                            mode=mode, interpret=interpret)


def segment_reduce(senders: jax.Array, receivers: jax.Array, x: jax.Array,
                   n_out: int, reduce: str = "sum",
                   use_pallas: Optional[bool] = None,
                   interpret: bool = False) -> jax.Array:
    """Graph message passing primitive: out[r] = reduce_e x[senders[e]]."""
    if use_pallas is None:
        use_pallas = _on_tpu()
    if not use_pallas:
        return ref.segment_reduce_ref(senders, receivers, x, n_out, reduce)
    order, row_off, dst_loc = build_blocked_csr(receivers, n_out)
    return csr_segment_reduce(senders[order].astype(jnp.int32), row_off,
                              dst_loc, x, n_out, reduce=reduce,
                              interpret=interpret)


def spmm(senders: jax.Array, receivers: jax.Array, x: jax.Array,
         **kw) -> jax.Array:
    """A @ X for an edge-list adjacency (destination-major)."""
    return segment_reduce(senders, receivers, x, x.shape[0], "sum", **kw)


def summary_spmm(x, n2s, n_super, p_src, p_dst, cp_src, cp_dst,
                 cm_src, cm_dst, self_loop_super) -> jax.Array:
    """A @ X straight from (G*, C): |P|+|C+|+|C-| work instead of |E|.

    The beyond-paper integration: when phi/|E| < 1, message passing over the
    summary moves fewer bytes and does fewer FLOPs than over raw edges.
    """
    return ref.summary_spmm_ref(x, n2s, n_super, p_src, p_dst,
                                cp_src, cp_dst, cm_src, cm_dst,
                                self_loop_super)


def embedding_bag(table: jax.Array, indices: jax.Array, offsets: jax.Array,
                  mode: str = "sum", use_pallas: Optional[bool] = None,
                  interpret: bool = False) -> jax.Array:
    """EmbeddingBag (JAX has no native one): ragged gather + segment reduce."""
    if use_pallas is None:
        use_pallas = _on_tpu()
    if not use_pallas:
        return ref.embedding_bag_ref(table, indices, offsets, mode)
    b = offsets.shape[0] - 1
    bag_ids = (jnp.searchsorted(offsets, jnp.arange(indices.shape[0]),
                                side="right") - 1).astype(jnp.int32)
    out = segment_reduce(indices.astype(jnp.int32), bag_ids, table, b,
                         "sum", use_pallas=True, interpret=interpret)
    if mode == "mean":
        counts = jnp.maximum(offsets[1:] - offsets[:-1], 1)
        out = out / counts[:, None].astype(out.dtype)
    return out


def attention(q: jax.Array, k: jax.Array, v: jax.Array, causal: bool = True,
              bias: Optional[jax.Array] = None,
              use_pallas: Optional[bool] = None,
              interpret: bool = False) -> jax.Array:
    """Multi-head attention with GQA; Pallas flash kernel on TPU."""
    if use_pallas is None:
        use_pallas = _on_tpu()
    if (not use_pallas) or bias is not None or q.shape[2] % 128 or k.shape[2] % 128:
        return ref.flash_attention_ref(q, k, v, causal, bias)
    return _flash_pallas(q, k, v, causal=causal, interpret=interpret)


def minhash_signature(senders: jax.Array, receivers: jax.Array,
                      n_nodes: int, seed: int = 0,
                      use_pallas: Optional[bool] = None,
                      interpret: bool = False) -> jax.Array:
    """Bulk min-hash signatures (coarse clustering over a whole snapshot)."""
    if use_pallas is None:
        use_pallas = _on_tpu()
    if not use_pallas:
        return ref.minhash_signature_ref(senders, receivers, n_nodes, seed)
    h = ref._mixhash(senders.astype(jnp.uint32), jnp.uint32(seed))
    out = segment_reduce(jnp.arange(senders.shape[0], dtype=jnp.int32),
                         receivers, h.astype(jnp.float32)[:, None],
                         n_nodes, "min", use_pallas=True, interpret=interpret)
    deg = jax.ops.segment_sum(jnp.ones_like(receivers), receivers,
                              num_segments=n_nodes)
    # isolated nodes carry NO_CLUSTER (match ref.py semantics)
    return jnp.where(deg > 0, out[:, 0].astype(jnp.int32),
                     jnp.int32(2**31 - 1))
