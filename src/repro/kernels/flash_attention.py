"""Pallas TPU kernel: block online-softmax attention (train/prefill path).

Classic FlashAttention tiling adapted to the TPU memory hierarchy:
(BQ, D) query tiles stay VMEM-resident while (BK, D) key/value tiles stream
through; the running max/denominator live in VREGs.  Tile sizes default to
128 to match the MXU systolic array.  GQA is handled by mapping each query
head to its kv group in the BlockSpec index maps (no jnp.repeat, so the KV
tensor is never physically expanded — that is the TPU-native win over the
naive path).

Used by the LM architectures when running on TPU; the pure-jnp oracle in
ref.py is the execution path on CPU and the semantics of record.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

_NEG_INF = -1e30


def _attn_kernel(q_ref, k_ref, v_ref, o_ref, *, bq: int, bk: int,
                 causal: bool, sm_scale: float, kv_len: int):
    qi = pl.program_id(2)
    q = q_ref[0, 0].astype(jnp.float32) * sm_scale        # [bq, d]

    m = jnp.full((bq,), _NEG_INF, jnp.float32)
    l = jnp.zeros((bq,), jnp.float32)
    acc = jnp.zeros((bq, q.shape[-1]), jnp.float32)

    n_kb = kv_len // bk

    def body(kb, carry):
        m, l, acc = carry
        k = k_ref[0, 0, kb, :, :].astype(jnp.float32)      # [bk, d]
        v = v_ref[0, 0, kb, :, :].astype(jnp.float32)
        s = q @ k.T                                        # [bq, bk]
        if causal:
            qpos = qi * bq + jax.lax.iota(jnp.int32, bq)[:, None]
            kpos = kb * bk + jax.lax.iota(jnp.int32, bk)[None, :]
            s = jnp.where(qpos >= kpos, s, _NEG_INF)
        m_new = jnp.maximum(m, s.max(axis=-1))
        p = jnp.exp(s - m_new[:, None])
        scale = jnp.exp(m - m_new)
        l_new = l * scale + p.sum(axis=-1)
        acc_new = acc * scale[:, None] + p @ v
        return m_new, l_new, acc_new

    if causal:
        # skip key blocks entirely above the diagonal of this query block
        last = (qi + 1) * bq
        n_kb_eff = jnp.minimum((last + bk - 1) // bk, n_kb)
        m, l, acc = jax.lax.fori_loop(0, n_kb_eff, body, (m, l, acc))
    else:
        m, l, acc = jax.lax.fori_loop(0, n_kb, body, (m, l, acc))

    o_ref[0, 0] = (acc / jnp.maximum(l, 1e-30)[:, None]).astype(o_ref.dtype)


def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, bq: int = 128, bk: int = 128,
                    interpret: bool = False) -> jax.Array:
    """q: [B, H, Tq, D]; k, v: [B, Hkv, Tk, D] with H % Hkv == 0."""
    b, h, tq, d = q.shape
    hkv, tk = k.shape[1], k.shape[2]
    assert h % hkv == 0 and tq % bq == 0 and tk % bk == 0
    group = h // hkv
    sm_scale = 1.0 / (d ** 0.5)

    kernel = functools.partial(_attn_kernel, bq=bq, bk=bk, causal=causal,
                               sm_scale=sm_scale, kv_len=tk)
    kr = k.reshape(b, hkv, tk // bk, bk, d)
    vr = v.reshape(b, hkv, tk // bk, bk, d)

    out = pl.pallas_call(
        kernel,
        grid=(b, h, tq // bq),
        in_specs=[
            pl.BlockSpec((1, 1, bq, d), lambda ib, ih, iq: (ib, ih, iq, 0)),
            # kv tile indexed by the query head's GQA group
            pl.BlockSpec((1, 1, tk // bk, bk, d),
                         lambda ib, ih, iq: (ib, ih // group, 0, 0, 0)),
            pl.BlockSpec((1, 1, tk // bk, bk, d),
                         lambda ib, ih, iq: (ib, ih // group, 0, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, d), lambda ib, ih, iq: (ib, ih, iq, 0)),
        out_shape=jax.ShapeDtypeStruct((b, h, tq, d), q.dtype),
        interpret=interpret,
    )(q, kr, vr)
    return out
