"""Shared model building blocks (pure-jnp, pjit/shard_map friendly)."""
from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp

Params = Dict[str, Any]


def dense_init(key, shape, scale: float | None = None, dtype=jnp.float32):
    fan_in = shape[0] if len(shape) > 1 else 1
    scale = scale if scale is not None else fan_in ** -0.5
    return (jax.random.truncated_normal(key, -2, 2, shape, jnp.float32)
            * scale).astype(dtype)


def rms_norm(x: jax.Array, w: jax.Array, eps: float = 1e-6) -> jax.Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    x = x * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps)
    return (x * w.astype(jnp.float32)).astype(dt)


def layer_norm(x: jax.Array, w: jax.Array, b: jax.Array,
               eps: float = 1e-6) -> jax.Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean((x - mu) ** 2, axis=-1, keepdims=True)
    y = (x - mu) * jax.lax.rsqrt(var + eps)
    return (y * w.astype(jnp.float32) + b.astype(jnp.float32)).astype(dt)


def rope_freqs(d: int, max_pos: int, base: float = 10000.0) -> jax.Array:
    inv = 1.0 / (base ** (jnp.arange(0, d, 2, dtype=jnp.float32) / d))
    t = jnp.arange(max_pos, dtype=jnp.float32)
    return jnp.outer(t, inv)  # [max_pos, d//2]


def apply_rope(x: jax.Array, pos: jax.Array, base: float = 10000.0) -> jax.Array:
    """x: [..., T, D] with D even; pos: broadcastable int [..., T].

    Roll formulation: ``x * cos + sign * roll(x, D/2) * sin`` with full-width
    cos/sin tables.  Same rotation as the split-halves form element-for-
    element (identical up to ~1 ulp: ``base**(-2j/d)`` vs ``1/base**(2j/d)``
    round differently under XLA pow), but expressed with NO concatenate/slice
    over the feature dim:
    concatenating slices of a tensor-parallel-sharded operand miscompiles in
    the SPMD partitioner on the CPU backend (observed on jax 0.4.37 under
    ``xla_force_host_platform_device_count``; exercised by tests/test_dist.py
    whenever the packed kv projection is sharded finer than a head).
    """
    d = x.shape[-1]
    half = d // 2
    idx = jnp.arange(d, dtype=jnp.float32) % half
    inv = base ** (-2.0 * idx / d)
    ang = pos.astype(jnp.float32)[..., None] * inv          # [..., T, D]
    sign = jnp.where(jnp.arange(d) < half, -1.0, 1.0)
    xf = x.astype(jnp.float32)
    rot = jnp.roll(xf, half, axis=-1)
    return (xf * jnp.cos(ang) + sign * rot * jnp.sin(ang)).astype(x.dtype)


def swiglu(x: jax.Array, w_gate: jax.Array, w_up: jax.Array,
           w_down: jax.Array) -> jax.Array:
    return (jax.nn.silu(x @ w_gate) * (x @ w_up)) @ w_down


def cross_entropy(logits: jax.Array, labels: jax.Array,
                  mask: jax.Array | None = None) -> jax.Array:
    """Mean token NLL in fp32 (stable logsumexp)."""
    logits = logits.astype(jnp.float32)
    lse = jax.scipy.special.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = lse - ll
    if mask is not None:
        return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1)
    return jnp.mean(nll)


def param_count(params) -> int:
    return sum(int(p.size) for p in jax.tree.leaves(params))
