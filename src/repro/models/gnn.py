"""Assigned GNN architectures over a common edge-list GraphBatch.

All four archs (graphsage-reddit, egnn, dimenet, graphcast) consume the same
fixed-shape batch so every (arch × graph-shape) dry-run cell is well defined.
Message passing is `jax.ops.segment_sum` over an edge index (the JAX-native
SpMM per kernel_taxonomy §B.3/§B.11), routed through repro.kernels.ops so the
Pallas path engages on TPU.  The graph-summarization integration
(summary_spmm) is exposed for sum/mean-aggregating archs.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.dist import annotate
from repro.kernels import ops
from repro.models.common import dense_init, layer_norm

Params = Dict[str, Any]


class GraphBatch(NamedTuple):
    """Fixed-shape graph sample (padded; masks mark live entries)."""
    node_feat: jax.Array            # f32[N, F]
    senders: jax.Array              # i32[E]
    receivers: jax.Array            # i32[E]
    edge_mask: jax.Array            # bool[E]
    node_mask: jax.Array            # bool[N]
    labels: jax.Array               # i32[N] (node class) or f32[N, dy]
    coords: Optional[jax.Array] = None      # f32[N, 3] (egnn/dimenet)
    triplet_kj: Optional[jax.Array] = None  # i32[T] edge ids (dimenet)
    triplet_ji: Optional[jax.Array] = None  # i32[T]


@dataclasses.dataclass(frozen=True)
class GNNConfig:
    name: str = "gnn"
    arch: str = "graphsage"     # graphsage | egnn | dimenet | graphcast
    n_layers: int = 2
    d_hidden: int = 128
    d_in: int = 128
    n_classes: int = 16
    # dimenet
    n_rbf: int = 6
    n_sbf: int = 7
    n_bilinear: int = 8
    # graphcast
    n_mesh_frac: int = 4        # mesh nodes = N // n_mesh_frac
    aggregator: str = "sum"
    param_dtype: Any = jnp.float32


def _mlp_init(key, dims, dtype):
    ks = jax.random.split(key, len(dims) - 1)
    return [{"w": dense_init(k, (a, b), dtype=dtype), "b": jnp.zeros((b,), dtype)}
            for k, a, b in zip(ks, dims[:-1], dims[1:])]


def _mlp(layers, x, act=jax.nn.silu):
    for i, l in enumerate(layers):
        x = x @ l["w"] + l["b"]
        if i + 1 < len(layers):
            x = act(x)
    return x


def _agg(senders, receivers, msgs, n, mode="sum"):
    out = jax.ops.segment_sum(msgs, receivers, num_segments=n)
    if mode == "mean":
        deg = jax.ops.segment_sum(jnp.ones_like(receivers, msgs.dtype),
                                  receivers, num_segments=n)
        out = out / jnp.maximum(deg, 1)[:, None]
    return out


# --------------------------------------------------------------------------- #
# GraphSAGE (mean aggregator)
# --------------------------------------------------------------------------- #


def init_graphsage(cfg: GNNConfig, key) -> Params:
    ks = jax.random.split(key, cfg.n_layers + 1)
    dims = [cfg.d_in] + [cfg.d_hidden] * cfg.n_layers
    layers = []
    for i in range(cfg.n_layers):
        k1, k2 = jax.random.split(ks[i])
        layers.append({
            "w_self": dense_init(k1, (dims[i], dims[i + 1]), dtype=cfg.param_dtype),
            "w_nbr": dense_init(k2, (dims[i], dims[i + 1]), dtype=cfg.param_dtype),
        })
    return {"layers": layers,
            "head": dense_init(ks[-1], (cfg.d_hidden, cfg.n_classes),
                               dtype=cfg.param_dtype)}


def graphsage_forward(params: Params, g: GraphBatch, cfg: GNNConfig) -> jax.Array:
    h = g.node_feat
    n = h.shape[0]
    w = g.edge_mask[:, None].astype(h.dtype)
    deg = jax.ops.segment_sum(g.edge_mask.astype(h.dtype), g.receivers,
                              num_segments=n)
    inv_deg = (1.0 / jnp.maximum(deg, 1.0))[:, None]
    for l in params["layers"]:
        # Algebraic scheduling (EXPERIMENTS.md §Perf H4): mean-aggregation
        # commutes with the linear map, so project BEFORE gathering whenever
        # d_out < d_in — the edge gather/scatter then moves d_out-wide rows
        # (4.7x fewer bytes on the 602-feature reddit shapes, 11x on cora).
        # (The feature-sharded-constraint variant was measured and REFUTED:
        # GSPMD materializes full-width partial sums — see §Perf log.)
        if l["w_nbr"].shape[1] < h.shape[1]:
            z = h @ l["w_nbr"]
            msgs = z[g.senders] * w
            agg = _agg(g.senders, g.receivers, msgs, n, "sum") * inv_deg
        else:
            msgs = h[g.senders] * w
            agg = (_agg(g.senders, g.receivers, msgs, n, "sum")
                   * inv_deg) @ l["w_nbr"]
        h = jax.nn.relu(h @ l["w_self"] + agg)
        h = h / jnp.maximum(jnp.linalg.norm(h, axis=-1, keepdims=True), 1e-6)
    return h @ params["head"]


# --------------------------------------------------------------------------- #
# EGNN (E(n)-equivariant)
# --------------------------------------------------------------------------- #


def init_egnn(cfg: GNNConfig, key) -> Params:
    ks = jax.random.split(key, cfg.n_layers * 3 + 2)
    d = cfg.d_hidden
    layers = []
    for i in range(cfg.n_layers):
        layers.append({
            "phi_e": _mlp_init(ks[3 * i], [2 * d + 1, d, d], cfg.param_dtype),
            "phi_x": _mlp_init(ks[3 * i + 1], [d, d, 1], cfg.param_dtype),
            "phi_h": _mlp_init(ks[3 * i + 2], [2 * d, d, d], cfg.param_dtype),
        })
    return {"embed": dense_init(ks[-2], (cfg.d_in, d), dtype=cfg.param_dtype),
            "layers": layers,
            "head": dense_init(ks[-1], (d, cfg.n_classes), dtype=cfg.param_dtype)}


def egnn_forward(params: Params, g: GraphBatch, cfg: GNNConfig) -> jax.Array:
    h = g.node_feat @ params["embed"]
    x = g.coords
    n = h.shape[0]
    w = g.edge_mask[:, None].astype(h.dtype)
    for l in params["layers"]:
        diff = x[g.senders] - x[g.receivers]
        d2 = jnp.sum(diff * diff, axis=-1, keepdims=True)
        m = _mlp(l["phi_e"], jnp.concatenate(
            [h[g.senders], h[g.receivers], d2], axis=-1)) * w
        xw = jnp.tanh(_mlp(l["phi_x"], m))          # bounded coord gate
        x = x + _agg(g.senders, g.receivers, diff * xw * w, n) / (n + 1)
        magg = _agg(g.senders, g.receivers, m, n)
        h = h + _mlp(l["phi_h"], jnp.concatenate([h, magg], axis=-1))
    return h @ params["head"]


# --------------------------------------------------------------------------- #
# DimeNet (directional message passing with RBF/SBF bases)
# --------------------------------------------------------------------------- #


def _rbf(d, n_rbf, cutoff=5.0):
    """Bessel-style radial basis."""
    freq = jnp.arange(1, n_rbf + 1, dtype=jnp.float32) * jnp.pi
    dn = jnp.clip(d / cutoff, 1e-4, 1.0)
    return jnp.sin(freq * dn[..., None]) / dn[..., None]


def _sbf(angle, n_sbf):
    k = jnp.arange(n_sbf, dtype=jnp.float32)
    return jnp.cos(angle[..., None] * (k + 1.0))


def init_dimenet(cfg: GNNConfig, key) -> Params:
    ks = jax.random.split(key, cfg.n_layers * 4 + 3)
    d = cfg.d_hidden
    blocks = []
    for i in range(cfg.n_layers):
        blocks.append({
            "w_rbf": dense_init(ks[4 * i], (cfg.n_rbf, d), dtype=cfg.param_dtype),
            "w_sbf": dense_init(ks[4 * i + 1], (cfg.n_sbf, cfg.n_bilinear),
                                dtype=cfg.param_dtype),
            "bilinear": dense_init(ks[4 * i + 2], (cfg.n_bilinear, d, d),
                                   scale=0.1, dtype=cfg.param_dtype),
            "upd": _mlp_init(ks[4 * i + 3], [2 * d, d, d], cfg.param_dtype),
        })
    return {"embed": dense_init(ks[-3], (cfg.d_in, d), dtype=cfg.param_dtype),
            "msg0": _mlp_init(ks[-2], [2 * d + cfg.n_rbf, d, d], cfg.param_dtype),
            "blocks": blocks,
            "head": dense_init(ks[-1], (d, cfg.n_classes), dtype=cfg.param_dtype)}


def dimenet_forward(params: Params, g: GraphBatch, cfg: GNNConfig) -> jax.Array:
    h = g.node_feat @ params["embed"]
    n = h.shape[0]
    diff = g.coords[g.senders] - g.coords[g.receivers]
    dist = jnp.sqrt(jnp.sum(diff * diff, axis=-1) + 1e-9)
    rbf = _rbf(dist, cfg.n_rbf)
    w = g.edge_mask[:, None].astype(h.dtype)
    m = _mlp(params["msg0"], jnp.concatenate(
        [h[g.senders], h[g.receivers], rbf], axis=-1)) * w  # per-edge message

    for blk in params["blocks"]:
        # triplet interaction: edge (k->j) modulates edge (j->i) through the
        # angle between them (the quadratic gather regime of §B.3).
        tkj, tji = g.triplet_kj, g.triplet_ji
        d_kj, d_ji = diff[tkj], diff[tji]
        cosang = jnp.sum(d_kj * d_ji, axis=-1) / (
            jnp.linalg.norm(d_kj, axis=-1) * jnp.linalg.norm(d_ji, axis=-1) + 1e-9)
        sbf = _sbf(jnp.arccos(jnp.clip(cosang, -1 + 1e-6, 1 - 1e-6)), cfg.n_sbf)
        basis = sbf @ blk["w_sbf"]                          # [T, n_bilinear]
        inter = jnp.einsum("tb,bio,ti->to", basis, blk["bilinear"], m[tkj])
        t_agg = jax.ops.segment_sum(inter, tji, num_segments=m.shape[0])
        gate = rbf @ blk["w_rbf"]
        m = m + _mlp(blk["upd"], jnp.concatenate([m * gate, t_agg], axis=-1)) * w
    out = _agg(g.senders, g.receivers, m, n)
    return out @ params["head"]


# --------------------------------------------------------------------------- #
# GraphCast-style encoder-processor-decoder
# --------------------------------------------------------------------------- #


def init_graphcast(cfg: GNNConfig, key) -> Params:
    ks = jax.random.split(key, cfg.n_layers * 2 + 5)
    d = cfg.d_hidden
    proc = []
    for i in range(cfg.n_layers):
        proc.append({
            "edge": _mlp_init(ks[2 * i], [3 * d, d, d], cfg.param_dtype),
            "node": _mlp_init(ks[2 * i + 1], [2 * d, d, d], cfg.param_dtype),
            "ln_e": jnp.ones((d,), cfg.param_dtype),
            "ln_n": jnp.ones((d,), cfg.param_dtype),
        })
    return {
        "grid_embed": dense_init(ks[-5], (cfg.d_in, d), dtype=cfg.param_dtype),
        "g2m": _mlp_init(ks[-4], [2 * d, d, d], cfg.param_dtype),
        "processor": proc,
        "m2g": _mlp_init(ks[-3], [2 * d, d, d], cfg.param_dtype),
        "head": dense_init(ks[-1], (d, cfg.n_classes), dtype=cfg.param_dtype),
    }


def graphcast_forward(params: Params, g: GraphBatch, cfg: GNNConfig) -> jax.Array:
    """Encode grid->mesh, process on the mesh, decode mesh->grid.

    The assigned generic graph shapes are mapped onto GraphCast's
    encode-process-decode skeleton: mesh nodes are the first N//n_mesh_frac
    node ids, grid2mesh/mesh2mesh edges are the provided edges folded into
    the mesh id range (documented in DESIGN.md §Arch-applicability).
    """
    n = g.node_feat.shape[0]
    nm = max(1, n // cfg.n_mesh_frac)
    h_grid = g.node_feat @ params["grid_embed"]
    w = g.edge_mask[:, None].astype(h_grid.dtype)

    # encoder: grid -> mesh
    mesh_rcv = g.receivers % nm
    msgs = _mlp(params["g2m"], jnp.concatenate(
        [h_grid[g.senders], h_grid[mesh_rcv]], axis=-1)) * w
    h_mesh = _agg(g.senders, mesh_rcv, msgs, nm, cfg.aggregator)

    # processor: n_layers of residual message passing on the mesh
    ms, mr = g.senders % nm, g.receivers % nm
    e_feat = jnp.zeros((g.senders.shape[0], h_mesh.shape[1]), h_mesh.dtype)
    for blk in params["processor"]:
        e_in = jnp.concatenate([e_feat, h_mesh[ms], h_mesh[mr]], axis=-1)
        e_feat = e_feat + layer_norm(_mlp(blk["edge"], e_in) * w, blk["ln_e"],
                                     jnp.zeros_like(blk["ln_e"]))
        agg = _agg(ms, mr, e_feat * w, nm, cfg.aggregator)
        n_in = jnp.concatenate([h_mesh, agg], axis=-1)
        h_mesh = h_mesh + layer_norm(_mlp(blk["node"], n_in), blk["ln_n"],
                                     jnp.zeros_like(blk["ln_n"]))

    # decoder: mesh -> grid
    msgs = _mlp(params["m2g"], jnp.concatenate(
        [h_mesh[ms], h_grid[g.receivers]], axis=-1)) * w
    h_out = h_grid + _agg(ms, g.receivers, msgs, n, cfg.aggregator)
    return h_out @ params["head"]


# --------------------------------------------------------------------------- #
# dispatch table + loss
# --------------------------------------------------------------------------- #

GNN_INITS = {"graphsage": init_graphsage, "egnn": init_egnn,
             "dimenet": init_dimenet, "graphcast": init_graphcast}
GNN_FORWARDS = {"graphsage": graphsage_forward, "egnn": egnn_forward,
                "dimenet": dimenet_forward, "graphcast": graphcast_forward}


def init_gnn(cfg: GNNConfig, key) -> Params:
    return GNN_INITS[cfg.arch](cfg, key)


def gnn_forward(params: Params, g: GraphBatch, cfg: GNNConfig) -> jax.Array:
    return GNN_FORWARDS[cfg.arch](params, g, cfg)


def gnn_loss(params: Params, g: GraphBatch, cfg: GNNConfig) -> jax.Array:
    logits = gnn_forward(params, g, cfg).astype(jnp.float32)
    nll = -jax.nn.log_softmax(logits)[
        jnp.arange(logits.shape[0]), g.labels.astype(jnp.int32)]
    m = g.node_mask.astype(jnp.float32)
    return jnp.sum(nll * m) / jnp.maximum(jnp.sum(m), 1)
