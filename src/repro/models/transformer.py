"""LM transformer family: dense GQA, MLA (MiniCPM3/DeepSeek-style), MoE.

One configurable decoder-only stack covers the five assigned LM archs.
Layer parameters are stacked on a leading [L] axis and consumed with
``lax.scan`` (small HLO, tractable 512-device compiles) with a configurable
remat policy.  Serving uses a KV cache; MLA caches the *compressed* latent
(the paper-arch's signature memory win) with matrix-absorbed decode.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.dist import annotate
from repro.kernels import ops
from repro.models.common import (apply_rope, cross_entropy, dense_init,
                                 rms_norm)

Params = Dict[str, Any]


@dataclasses.dataclass(frozen=True)
class TransformerConfig:
    name: str = "lm"
    n_layers: int = 4
    d_model: int = 256
    n_heads: int = 4
    n_kv_heads: int = 4
    d_head: int = 64
    d_ff: int = 1024
    vocab: int = 1024
    max_seq: int = 8192
    attn: str = "gqa"          # "gqa" | "mla"
    # MoE (n_experts == 0 -> dense FFN)
    n_experts: int = 0
    top_k: int = 2
    capacity_factor: float = 1.25
    # MLA dims
    q_lora: int = 0            # 0 = full-rank q
    kv_lora: int = 256
    rope_dim: int = 32
    nope_dim: int = 64
    v_head_dim: int = 64
    # vocab padding: shard-friendly tables (Megatron's
    # make-vocab-size-divisible-by); padded logits are masked to -inf.
    pad_vocab_to: int = 256
    # numerics
    param_dtype: Any = jnp.bfloat16
    compute_dtype: Any = jnp.bfloat16
    remat: bool = True
    remat_policy: str = "full"   # full | dots (save matmul outputs)
    seq_shard: bool = True       # sequence-shard the residual stream (SP)
    # parallelism hints consumed by repro.dist.sharding
    fsdp_axes: Tuple[str, ...] = ("data",)

    @property
    def moe(self) -> bool:
        return self.n_experts > 0

    @property
    def vocab_padded(self) -> int:
        m = max(self.pad_vocab_to, 1)
        return (self.vocab + m - 1) // m * m


# --------------------------------------------------------------------------- #
# init
# --------------------------------------------------------------------------- #


def _layer_init(cfg: TransformerConfig, key) -> Params:
    ks = jax.random.split(key, 16)
    d, dt = cfg.d_model, cfg.param_dtype
    p: Params = {
        "ln_attn": jnp.ones((d,), dt),
        "ln_ffn": jnp.ones((d,), dt),
        "o_proj": dense_init(ks[3], (cfg.n_heads * _vdim(cfg), d), dtype=dt),
    }
    if cfg.attn == "gqa":
        p["q_proj"] = dense_init(ks[0], (d, cfg.n_heads * cfg.d_head), dtype=dt)
        p["k_proj"] = dense_init(ks[1], (d, cfg.n_kv_heads * cfg.d_head), dtype=dt)
        p["v_proj"] = dense_init(ks[2], (d, cfg.n_kv_heads * cfg.d_head), dtype=dt)
    else:  # MLA
        qd = cfg.nope_dim + cfg.rope_dim
        if cfg.q_lora:
            p["q_a"] = dense_init(ks[0], (d, cfg.q_lora), dtype=dt)
            p["q_a_norm"] = jnp.ones((cfg.q_lora,), dt)
            p["q_b"] = dense_init(ks[4], (cfg.q_lora, cfg.n_heads * qd), dtype=dt)
        else:
            p["q_proj"] = dense_init(ks[0], (d, cfg.n_heads * qd), dtype=dt)
        p["kv_a"] = dense_init(ks[1], (d, cfg.kv_lora + cfg.rope_dim), dtype=dt)
        p["kv_a_norm"] = jnp.ones((cfg.kv_lora,), dt)
        p["k_b"] = dense_init(ks[2], (cfg.kv_lora, cfg.n_heads * cfg.nope_dim), dtype=dt)
        p["v_b"] = dense_init(ks[5], (cfg.kv_lora, cfg.n_heads * cfg.v_head_dim), dtype=dt)
    if cfg.moe:
        e = cfg.n_experts
        p["router"] = dense_init(ks[6], (d, e), scale=d ** -0.5, dtype=jnp.float32)
        p["w_gate"] = dense_init(ks[7], (e, d, cfg.d_ff), dtype=dt)
        p["w_up"] = dense_init(ks[8], (e, d, cfg.d_ff), dtype=dt)
        p["w_down"] = dense_init(ks[9], (e, cfg.d_ff, d), dtype=dt)
    else:
        p["w_gate"] = dense_init(ks[7], (d, cfg.d_ff), dtype=dt)
        p["w_up"] = dense_init(ks[8], (d, cfg.d_ff), dtype=dt)
        p["w_down"] = dense_init(ks[9], (cfg.d_ff, d), dtype=dt)
    return p


def _vdim(cfg: TransformerConfig) -> int:
    return cfg.v_head_dim if cfg.attn == "mla" else cfg.d_head


def init_transformer(cfg: TransformerConfig, key) -> Params:
    k_emb, k_layers, k_head = jax.random.split(key, 3)
    layer_keys = jax.random.split(k_layers, cfg.n_layers)
    layers = jax.vmap(lambda k: _layer_init(cfg, k))(layer_keys)
    return {
        "embed": dense_init(k_emb, (cfg.vocab_padded, cfg.d_model), scale=1.0,
                            dtype=cfg.param_dtype),
        "layers": layers,
        "ln_f": jnp.ones((cfg.d_model,), cfg.param_dtype),
        "lm_head": dense_init(k_head, (cfg.d_model, cfg.vocab_padded),
                              dtype=cfg.param_dtype),
    }


# --------------------------------------------------------------------------- #
# attention
# --------------------------------------------------------------------------- #


def _gqa_qkv(p: Params, cfg: TransformerConfig, h: jax.Array, pos: jax.Array):
    b, t, _ = h.shape
    q = (h @ p["q_proj"]).reshape(b, t, cfg.n_heads, cfg.d_head)
    k = (h @ p["k_proj"]).reshape(b, t, cfg.n_kv_heads, cfg.d_head)
    v = (h @ p["v_proj"]).reshape(b, t, cfg.n_kv_heads, cfg.d_head)
    q = apply_rope(q.transpose(0, 2, 1, 3), pos[:, None, :])
    k = apply_rope(k.transpose(0, 2, 1, 3), pos[:, None, :])
    return q, k, v.transpose(0, 2, 1, 3)


def _mla_q(p: Params, cfg: TransformerConfig, h: jax.Array, pos: jax.Array):
    b, t, _ = h.shape
    qd = cfg.nope_dim + cfg.rope_dim
    if cfg.q_lora:
        qa = rms_norm(h @ p["q_a"], p["q_a_norm"])
        q = (qa @ p["q_b"]).reshape(b, t, cfg.n_heads, qd)
    else:
        q = (h @ p["q_proj"]).reshape(b, t, cfg.n_heads, qd)
    q = q.transpose(0, 2, 1, 3)
    q_nope, q_rope = q[..., :cfg.nope_dim], q[..., cfg.nope_dim:]
    q_rope = apply_rope(q_rope, pos[:, None, :])
    return q_nope, q_rope


def _mla_latent(p: Params, cfg: TransformerConfig, h: jax.Array, pos: jax.Array):
    """Compressed latent c_kv [B,T,kv_lora] + shared rope key [B,T,rope]."""
    kv = h @ p["kv_a"]
    c_kv = rms_norm(kv[..., :cfg.kv_lora], p["kv_a_norm"])
    k_rope = apply_rope(kv[..., None, cfg.kv_lora:].transpose(0, 2, 1, 3),
                        pos[:, None, :])[:, 0]  # [B,T,rope]
    return c_kv, k_rope


def _mla_attend(p: Params, cfg: TransformerConfig, q_nope, q_rope,
                c_kv, k_rope, causal: bool) -> jax.Array:
    """Matrix-absorbed MLA attention over the latent cache.

    scores = q_nope·(c_kv W_kb)^T + q_rope·k_rope^T computed WITHOUT
    expanding per-head keys: absorb W_kb into q (q_eff = q_nope @ W_kb^T per
    head), attend over the kv_lora-dim latent, then expand values through
    W_vb only at the end (DeepSeek-V2 style serving trick).
    """
    b, nh, t, _ = q_nope.shape
    w_kb = p["k_b"].reshape(cfg.kv_lora, nh, cfg.nope_dim)
    q_eff = jnp.einsum("bhtd,lhd->bhtl", q_nope, w_kb)        # [B,H,T,kv_lora]
    q_full = jnp.concatenate([q_eff, q_rope], axis=-1)
    k_full = jnp.concatenate([c_kv, k_rope], axis=-1)          # [B,S,l+r]
    k_full = k_full[:, None].astype(q_full.dtype)              # kv head = 1
    ctx = ops.attention(q_full, k_full, c_kv[:, None].astype(q_full.dtype),
                        causal=causal)                         # [B,H,T,kv_lora]
    w_vb = p["v_b"].reshape(cfg.kv_lora, nh, cfg.v_head_dim)
    return jnp.einsum("bhtl,lhv->bhtv", ctx, w_vb)


# --------------------------------------------------------------------------- #
# FFN / MoE
# --------------------------------------------------------------------------- #


def _dense_ffn(p: Params, h: jax.Array) -> jax.Array:
    return (jax.nn.silu(h @ p["w_gate"]) * (h @ p["w_up"])) @ p["w_down"]


def _moe_ffn(p: Params, cfg: TransformerConfig, h: jax.Array) -> jax.Array:
    """Top-k MoE with capacity-bucket dispatch (GShard-style, EP-shardable).

    Tokens are scattered into a [E, C, D] buffer (C = capacity) so the
    expert matmuls are dense batched GEMMs; with experts sharded over the
    'model' axis GSPMD turns the scatter/gather into all-to-alls.
    """
    b, t, d = h.shape
    e, k = cfg.n_experts, cfg.top_k
    # per-batch-row capacity buckets: every scatter/gather below carries a
    # leading batch dim, so under batch sharding GSPMD keeps them LOCAL and
    # only the expert einsums move data (EXPERIMENTS.md §Perf H1').
    cap = max(1, int(t * k / e * cfg.capacity_factor))

    logits = h.astype(jnp.float32) @ p["router"]               # [B,t,E]
    probs = jax.nn.softmax(logits, axis=-1)
    gate, idx = jax.lax.top_k(probs, k)                        # [B,t,k]
    gate = gate / jnp.maximum(gate.sum(-1, keepdims=True), 1e-9)

    flat = idx.reshape(b, t * k)                               # expert ids
    oh = jax.nn.one_hot(flat, e, dtype=jnp.int32)              # [B,t*k,E]
    rank_all = jnp.cumsum(oh, axis=1) - 1
    rank = jnp.take_along_axis(rank_all, flat[..., None],
                               axis=2)[..., 0]                 # [B,t*k]
    keep = rank < cap
    slot = jnp.where(keep, rank, 0)
    rows = jnp.arange(b)[:, None]
    tok_in_row = jnp.arange(t * k) // k                        # [t*k]

    buf = jnp.zeros((b, e, cap, d), h.dtype)
    upd = jnp.where(keep[..., None], h[:, tok_in_row, :], 0)
    buf = buf.at[rows, flat, slot].add(upd)                    # batched scatter

    y = jax.nn.silu(jnp.einsum("becd,edf->becf", buf, p["w_gate"]))
    y = y * jnp.einsum("becd,edf->becf", buf, p["w_up"])
    y = jnp.einsum("becf,efd->becd", y, p["w_down"])

    out = y[rows, flat, slot]                                  # batched gather
    out = jnp.where(keep[..., None], out, 0)
    out = out.reshape(b, t, k, d) * gate[..., None].astype(out.dtype)
    # aux load-balance loss (Switch): returned via side channel if needed
    return out.sum(axis=2)


# --------------------------------------------------------------------------- #
# forward / decode
# --------------------------------------------------------------------------- #


def _layer_fn(cfg: TransformerConfig, h: jax.Array, pos: jax.Array,
              p: Params) -> jax.Array:
    if cfg.seq_shard:
        # Megatron-SP: the inter-layer residual is the dominant live
        # activation under scan+remat; shard its seq dim over 'model' so the
        # per-device footprint is B*T*D/(dp*tp), not B*T*D/dp (§Perf H3).
        h = annotate.constrain(h, annotate.data_axes(), "model", None)
    x = rms_norm(h, p["ln_attn"])
    b, t, _ = h.shape
    if cfg.attn == "gqa":
        q, k, v = _gqa_qkv(p, cfg, x, pos)
        ctx = ops.attention(q, k, v, causal=True)
    else:
        q_nope, q_rope = _mla_q(p, cfg, x, pos)
        c_kv, k_rope = _mla_latent(p, cfg, x, pos)
        ctx = _mla_attend(p, cfg, q_nope, q_rope, c_kv, k_rope, causal=True)
    ctx = ctx.transpose(0, 2, 1, 3).reshape(b, t, -1)
    h = h + ctx @ p["o_proj"]
    x = rms_norm(h, p["ln_ffn"])
    ffn = _moe_ffn(p, cfg, x) if cfg.moe else _dense_ffn(p, x)
    return h + ffn


def forward(params: Params, tokens: jax.Array, cfg: TransformerConfig,
            ) -> jax.Array:
    """tokens [B, T] -> logits [B, T, V]; scan over stacked layers."""
    h = params["embed"][tokens].astype(cfg.compute_dtype)
    pos = jnp.broadcast_to(jnp.arange(tokens.shape[1]), tokens.shape)

    def body(h, lp):
        return _layer_fn(cfg, h, pos, lp), None

    if cfg.remat:
        import os
        policy_name = os.environ.get("REPRO_REMAT_POLICY", cfg.remat_policy)
        policy = (jax.checkpoint_policies.dots_with_no_batch_dims_saveable
                  if policy_name == "dots" else None)
        body = jax.checkpoint(body, prevent_cse=False, policy=policy)
    h, _ = jax.lax.scan(body, h, params["layers"])
    h = rms_norm(h, params["ln_f"])
    logits = h @ params["lm_head"].astype(cfg.compute_dtype)
    return _mask_pad_vocab(logits, cfg)


def _mask_pad_vocab(logits: jax.Array, cfg: TransformerConfig) -> jax.Array:
    if cfg.vocab_padded == cfg.vocab:
        return logits
    pad = jnp.arange(cfg.vocab_padded) >= cfg.vocab
    return jnp.where(pad, jnp.asarray(-1e30, logits.dtype), logits)


def loss_fn(params: Params, tokens: jax.Array, labels: jax.Array,
            cfg: TransformerConfig) -> jax.Array:
    logits = forward(params, tokens, cfg)
    return cross_entropy(logits, labels)


# ------------------------------------------------------------------ serving


def init_cache(cfg: TransformerConfig, batch: int, max_len: int) -> Params:
    if cfg.attn == "mla":
        return {
            "c_kv": jnp.zeros((cfg.n_layers, batch, max_len, cfg.kv_lora),
                              cfg.compute_dtype),
            "k_rope": jnp.zeros((cfg.n_layers, batch, max_len, cfg.rope_dim),
                                cfg.compute_dtype),
            "len": jnp.zeros((), jnp.int32),
        }
    return {
        "k": jnp.zeros((cfg.n_layers, batch, cfg.n_kv_heads, max_len,
                        cfg.d_head), cfg.compute_dtype),
        "v": jnp.zeros((cfg.n_layers, batch, cfg.n_kv_heads, max_len,
                        cfg.d_head), cfg.compute_dtype),
        "len": jnp.zeros((), jnp.int32),
    }


def decode_step(params: Params, cache: Params, tokens: jax.Array,
                cfg: TransformerConfig) -> Tuple[jax.Array, Params]:
    """One-token decode: tokens [B] -> logits [B, V], updated cache.

    Attention is O(cache_len) per token (linear, never quadratic); masking
    handles the ragged live length.
    """
    b = tokens.shape[0]
    t_now = cache["len"]
    h = params["embed"][tokens][:, None].astype(cfg.compute_dtype)  # [B,1,D]
    pos = jnp.full((b, 1), t_now, jnp.int32)
    max_len = (cache["c_kv"].shape[2] if cfg.attn == "mla"
               else cache["k"].shape[3])
    span = jnp.arange(max_len)
    live = (span <= t_now)[None, None, None, :]                 # [1,1,1,S]
    bias = jnp.where(live, 0.0, -1e30).astype(jnp.float32)

    new_cache = dict(cache)

    def layer(i, h):
        p = jax.tree.map(lambda a: a[i], params["layers"])
        x = rms_norm(h, p["ln_attn"])
        if cfg.attn == "gqa":
            q, k1, v1 = _gqa_qkv(p, cfg, x, pos)
            k_all = jax.lax.dynamic_update_index_in_dim(
                cache["k"][i], k1[:, :, 0], t_now, 2)
            v_all = jax.lax.dynamic_update_index_in_dim(
                cache["v"][i], v1[:, :, 0], t_now, 2)
            ctx = ops.attention(q, k_all, v_all, causal=False, bias=bias)
            upd = (k_all, v_all)
        else:
            q_nope, q_rope = _mla_q(p, cfg, x, pos)
            c1, r1 = _mla_latent(p, cfg, x, pos)
            c_all = jax.lax.dynamic_update_index_in_dim(
                cache["c_kv"][i], c1[:, 0], t_now, 1)
            r_all = jax.lax.dynamic_update_index_in_dim(
                cache["k_rope"][i], r1[:, 0], t_now, 1)
            w_kb = p["k_b"].reshape(cfg.kv_lora, cfg.n_heads, cfg.nope_dim)
            q_eff = jnp.einsum("bhtd,lhd->bhtl", q_nope, w_kb)
            q_full = jnp.concatenate([q_eff, q_rope], axis=-1)
            k_full = jnp.concatenate([c_all, r_all], axis=-1)[:, None]
            ctx = ops.attention(q_full, k_full.astype(q_full.dtype),
                                c_all[:, None].astype(q_full.dtype),
                                causal=False, bias=bias)
            w_vb = p["v_b"].reshape(cfg.kv_lora, cfg.n_heads, cfg.v_head_dim)
            ctx = jnp.einsum("bhtl,lhv->bhtv", ctx, w_vb)
            upd = (c_all, r_all)
        ctx = ctx.transpose(0, 2, 1, 3).reshape(b, 1, -1)
        h = h + ctx @ p["o_proj"]
        x = rms_norm(h, p["ln_ffn"])
        ffn = _moe_ffn(p, cfg, x) if cfg.moe else _dense_ffn(p, x)
        return h + ffn, upd

    # scan over layers, threading per-layer cache updates
    def body(h, xs):
        i = xs
        h, upd = layer(i, h)
        return h, upd

    h2, upds = jax.lax.scan(body, h, jnp.arange(cfg.n_layers))
    if cfg.attn == "mla":
        new_cache["c_kv"], new_cache["k_rope"] = upds
    else:
        new_cache["k"], new_cache["v"] = upds
    new_cache["len"] = t_now + 1
    h2 = rms_norm(h2, params["ln_f"])
    logits = (h2 @ params["lm_head"].astype(cfg.compute_dtype))[:, 0]
    return _mask_pad_vocab(logits, cfg), new_cache
