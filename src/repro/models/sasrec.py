"""SASRec (self-attentive sequential recommendation, arXiv:1808.09781).

Item-embedding table (the huge-sparse-table regime of kernel_taxonomy §B.6)
+ 2 causal self-attention blocks over length-50 user histories.  Four
serving shapes are first-class: train (in-batch BCE with sampled negatives),
online p99 scoring, offline bulk scoring, and 1M-candidate retrieval
(batched dot, never a loop).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.kernels import ops
from repro.models.common import dense_init, layer_norm

Params = Dict[str, Any]


@dataclasses.dataclass(frozen=True)
class SASRecConfig:
    name: str = "sasrec"
    n_items: int = 1_000_000
    embed_dim: int = 50
    n_blocks: int = 2
    n_heads: int = 1
    seq_len: int = 50
    dropout: float = 0.0
    param_dtype: Any = jnp.float32


def init_sasrec(cfg: SASRecConfig, key) -> Params:
    ks = jax.random.split(key, 2 + 6 * cfg.n_blocks)
    d = cfg.embed_dim
    blocks = []
    for i in range(cfg.n_blocks):
        b = ks[2 + 6 * i: 2 + 6 * (i + 1)]
        blocks.append({
            "wq": dense_init(b[0], (d, d), dtype=cfg.param_dtype),
            "wk": dense_init(b[1], (d, d), dtype=cfg.param_dtype),
            "wv": dense_init(b[2], (d, d), dtype=cfg.param_dtype),
            "wo": dense_init(b[3], (d, d), dtype=cfg.param_dtype),
            "ff1": dense_init(b[4], (d, d), dtype=cfg.param_dtype),
            "ff2": dense_init(b[5], (d, d), dtype=cfg.param_dtype),
            "ln1": jnp.ones((d,), cfg.param_dtype),
            "ln2": jnp.ones((d,), cfg.param_dtype),
        })
    return {
        "item_emb": dense_init(ks[0], (cfg.n_items, d), scale=d ** -0.5,
                               dtype=cfg.param_dtype),
        "pos_emb": dense_init(ks[1], (cfg.seq_len, d), scale=0.02,
                              dtype=cfg.param_dtype),
        "blocks": blocks,
        "ln_f": jnp.ones((d,), cfg.param_dtype),
    }


def encode(params: Params, seq: jax.Array, cfg: SASRecConfig) -> jax.Array:
    """seq: int32[B, L] item ids (0 = padding) -> representations [B, L, D]."""
    b, L = seq.shape
    h = params["item_emb"][seq] + params["pos_emb"][None, :L]
    pad = (seq == 0)[..., None]
    h = jnp.where(pad, 0.0, h)
    nh = cfg.n_heads
    dh = cfg.embed_dim // nh
    for blk in params["blocks"]:
        x = layer_norm(h, blk["ln1"], jnp.zeros_like(blk["ln1"]))
        q = (x @ blk["wq"]).reshape(b, L, nh, dh).transpose(0, 2, 1, 3)
        k = (x @ blk["wk"]).reshape(b, L, nh, dh).transpose(0, 2, 1, 3)
        v = (x @ blk["wv"]).reshape(b, L, nh, dh).transpose(0, 2, 1, 3)
        ctx = ops.attention(q, k, v, causal=True)
        ctx = ctx.transpose(0, 2, 1, 3).reshape(b, L, cfg.embed_dim)
        h = h + ctx @ blk["wo"]
        x = layer_norm(h, blk["ln2"], jnp.zeros_like(blk["ln2"]))
        h = h + jax.nn.relu(x @ blk["ff1"]) @ blk["ff2"]
        h = jnp.where(pad, 0.0, h)
    return layer_norm(h, params["ln_f"], jnp.zeros_like(params["ln_f"]))


def train_loss(params: Params, seq: jax.Array, pos: jax.Array,
               neg: jax.Array, cfg: SASRecConfig) -> jax.Array:
    """BCE over (positive next item, sampled negative) per position."""
    h = encode(params, seq, cfg)
    pe = params["item_emb"][pos]
    ne = params["item_emb"][neg]
    pos_logit = jnp.sum(h * pe, axis=-1).astype(jnp.float32)
    neg_logit = jnp.sum(h * ne, axis=-1).astype(jnp.float32)
    mask = (pos != 0).astype(jnp.float32)
    loss = (jax.nn.softplus(-pos_logit) + jax.nn.softplus(neg_logit)) * mask
    return jnp.sum(loss) / jnp.maximum(jnp.sum(mask), 1)


def score_candidates(params: Params, seq: jax.Array, candidates: jax.Array,
                     cfg: SASRecConfig) -> jax.Array:
    """User-state vs candidate scores: [B, L] x i32[C] -> f32[B, C].

    The retrieval_cand shape (B=1, C=1e6) is one [1, D] @ [D, C] GEMM.
    """
    h = encode(params, seq, cfg)[:, -1]                  # [B, D]
    emb = params["item_emb"][candidates]                 # [C, D]
    return (h @ emb.T).astype(jnp.float32)


def serve_topk(params: Params, seq: jax.Array, candidates: jax.Array,
               cfg: SASRecConfig, k: int = 10) -> Tuple[jax.Array, jax.Array]:
    scores = score_candidates(params, seq, candidates, cfg)
    return jax.lax.top_k(scores, k)
