"""Neighbor sampling for minibatch GNN training (GraphSAGE fanouts).

``minibatch_lg`` requires a real sampler: given a CSR adjacency, sample a
fixed fanout per hop around seed nodes, emitting a fixed-shape padded
subgraph (GraphBatch) ready for the device.  Host-side numpy (the sampler is
I/O-bound in production; devices only see dense tensors).
"""
from __future__ import annotations

from typing import List, Sequence, Tuple

import numpy as np


class CSRGraph:
    def __init__(self, n_nodes: int, senders: np.ndarray, receivers: np.ndarray):
        order = np.argsort(receivers, kind="stable")
        self.indices = senders[order].astype(np.int32)
        counts = np.bincount(receivers, minlength=n_nodes)
        self.indptr = np.concatenate([[0], np.cumsum(counts)]).astype(np.int64)
        self.n_nodes = n_nodes

    def neighbors(self, u: int) -> np.ndarray:
        return self.indices[self.indptr[u]:self.indptr[u + 1]]


def sample_fanout(g: CSRGraph, seeds: np.ndarray, fanouts: Sequence[int],
                  rng: np.random.Generator,
                  ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """k-hop fanout sampling; returns (nodes, senders, receivers).

    ``nodes[0:len(seeds)] == seeds``; edge endpoints index into ``nodes``.
    """
    node_ids: List[int] = list(map(int, seeds))
    pos = {int(u): i for i, u in enumerate(seeds)}
    frontier = list(map(int, seeds))
    s_out: List[int] = []
    r_out: List[int] = []
    for fan in fanouts:
        nxt: List[int] = []
        for u in frontier:
            nbrs = g.neighbors(u)
            if len(nbrs) == 0:
                continue
            take = rng.choice(nbrs, size=min(fan, len(nbrs)), replace=False)
            for v in map(int, take):
                if v not in pos:
                    pos[v] = len(node_ids)
                    node_ids.append(v)
                    nxt.append(v)
                s_out.append(pos[v])
                r_out.append(pos[u])
        frontier = nxt
    return (np.asarray(node_ids, np.int32),
            np.asarray(s_out, np.int32), np.asarray(r_out, np.int32))


def pad_subgraph(nodes: np.ndarray, senders: np.ndarray, receivers: np.ndarray,
                 n_pad: int, e_pad: int):
    """Fixed-shape padding (node 0 self-loops on dead edge slots)."""
    n, e = len(nodes), len(senders)
    assert n <= n_pad and e <= e_pad, (n, n_pad, e, e_pad)
    node_mask = np.zeros(n_pad, bool)
    node_mask[:n] = True
    edge_mask = np.zeros(e_pad, bool)
    edge_mask[:e] = True
    nodes_p = np.zeros(n_pad, np.int32)
    nodes_p[:n] = nodes
    s_p = np.zeros(e_pad, np.int32)
    s_p[:e] = senders
    r_p = np.zeros(e_pad, np.int32)
    r_p[:e] = receivers
    return nodes_p, s_p, r_p, node_mask, edge_mask


def build_triplets(senders: np.ndarray, receivers: np.ndarray,
                   max_per_edge: int, rng: np.random.Generator,
                   ) -> Tuple[np.ndarray, np.ndarray]:
    """(k->j, j->i) directional triplets for DimeNet, capped per edge.

    The cap bounds the O(sum deg^2) triplet blow-up on non-molecular graphs
    (documented in DESIGN.md); molecule-scale graphs are exact.
    """
    in_edges: dict = {}
    for e, r in enumerate(receivers):
        in_edges.setdefault(int(r), []).append(e)
    t_kj: List[int] = []
    t_ji: List[int] = []
    for e_ji, j in enumerate(senders):
        cands = [e for e in in_edges.get(int(j), ())
                 if int(senders[e]) != int(receivers[e_ji])]
        if len(cands) > max_per_edge:
            cands = list(rng.choice(cands, size=max_per_edge, replace=False))
        for e_kj in cands:
            t_kj.append(e_kj)
            t_ji.append(e_ji)
    if not t_kj:
        t_kj, t_ji = [0], [0]
    return np.asarray(t_kj, np.int32), np.asarray(t_ji, np.int32)
