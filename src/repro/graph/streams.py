"""Fully dynamic graph stream generation (Sect. 2.1 / Sect. 4.1).

The paper builds insertion-only streams by ordering a graph's edges, and
fully dynamic streams by inserting all edges in random order and, for each
edge, emitting a deletion with probability 0.1 at a random later position.
We reproduce both constructions, plus the synthetic generators used in the
appendix experiments (copying model [14] with copy probability beta; also
Barabási–Albert [1] for the preferential-attachment scalability setting).
"""
from __future__ import annotations

import random
from typing import Iterable, List, Sequence, Set, Tuple

Change = Tuple[int, int, bool]  # (u, v, is_insert)


def edges_to_insertion_stream(edges: Sequence[Tuple[int, int]],
                              seed: int = 0, shuffle: bool = True,
                              ) -> List[Change]:
    """Insertion-only (IO) stream: randomly ordered unless timestamps exist."""
    rng = random.Random(seed)
    order = list(edges)
    if shuffle:
        rng.shuffle(order)
    return [(u, v, True) for (u, v) in order]


def edges_to_fully_dynamic_stream(edges: Sequence[Tuple[int, int]],
                                  delete_prob: float = 0.1,
                                  seed: int = 0) -> List[Change]:
    """FD stream per Sect. 4.1: each inserted edge is later deleted w.p. 0.1.

    Deletions are placed at a uniformly random position after the matching
    insertion, preserving stream soundness (no deletion of a missing edge,
    no duplicate insertion of a live edge).
    """
    rng = random.Random(seed)
    order = list(edges)
    rng.shuffle(order)
    stream: List[Change] = [(u, v, True) for (u, v) in order]
    n = len(stream)
    deletions: List[Tuple[int, Change]] = []
    for i, (u, v, _) in enumerate(list(stream)):
        if rng.random() < delete_prob:
            pos = rng.randint(i + 1, n)
            deletions.append((pos, (u, v, False)))
    # stable insert by target position (later positions first keeps indices valid)
    for pos, ch in sorted(deletions, key=lambda x: -x[0]):
        stream.insert(pos, ch)
    return stream


# --------------------------------------------------------------------------- #
# synthetic graph generators
# --------------------------------------------------------------------------- #

def copying_model_edges(n_nodes: int, out_deg: int, beta: float,
                        seed: int = 0) -> List[Tuple[int, int]]:
    """Kleinberg et al. copying model [14] (Appendix A.2, Fig. 7a).

    Each new node copies the endpoints of a random existing node's edges with
    probability ``beta`` and links uniformly at random otherwise.  Output is
    symmetrized with self-loops/multi-edges removed, as in the paper.
    """
    rng = random.Random(seed)
    edges: Set[Tuple[int, int]] = set()
    targets: List[List[int]] = [[] for _ in range(n_nodes)]
    for u in range(1, n_nodes):
        proto = rng.randrange(u)
        proto_targets = targets[proto]
        for j in range(out_deg):
            if proto_targets and rng.random() < beta:
                v = proto_targets[min(j, len(proto_targets) - 1)]
            else:
                v = rng.randrange(u)
            if v != u:
                e = (min(u, v), max(u, v))
                if e not in edges:
                    edges.add(e)
                    targets[u].append(v)
    return sorted(edges)


def barabasi_albert_edges(n_nodes: int, m: int, seed: int = 0,
                          ) -> List[Tuple[int, int]]:
    """BA preferential attachment [1]: the paper's scalability assumption."""
    rng = random.Random(seed)
    edges: Set[Tuple[int, int]] = set()
    repeated: List[int] = list(range(min(m + 1, n_nodes)))
    for u in range(m + 1, n_nodes):
        chosen: Set[int] = set()
        while len(chosen) < m:
            chosen.add(rng.choice(repeated))
        for v in chosen:
            edges.add((min(u, v), max(u, v)))
            repeated.extend((u, v))
    return sorted(edges)


def erdos_renyi_edges(n_nodes: int, n_edges: int, seed: int = 0,
                      ) -> List[Tuple[int, int]]:
    rng = random.Random(seed)
    edges: Set[Tuple[int, int]] = set()
    while len(edges) < n_edges:
        u = rng.randrange(n_nodes)
        v = rng.randrange(n_nodes)
        if u != v:
            edges.add((min(u, v), max(u, v)))
    return sorted(edges)


def sbm_edges(n_nodes: int, n_blocks: int, p_in: float, p_out: float,
              seed: int = 0) -> List[Tuple[int, int]]:
    """Stochastic block model — dense communities compress well (Sect. 3.3)."""
    rng = random.Random(seed)
    block = [rng.randrange(n_blocks) for _ in range(n_nodes)]
    edges: List[Tuple[int, int]] = []
    for u in range(n_nodes):
        for v in range(u + 1, n_nodes):
            p = p_in if block[u] == block[v] else p_out
            if rng.random() < p:
                edges.append((u, v))
    return edges


def validate_stream(stream: Iterable[Change]) -> bool:
    """Soundness check of Sect. 2.1 (insert-new / delete-existing only)."""
    live: Set[Tuple[int, int]] = set()
    for (u, v, ins) in stream:
        e = (min(u, v), max(u, v))
        if ins:
            if e in live or u == v:
                return False
            live.add(e)
        else:
            if e not in live:
                return False
            live.remove(e)
    return True
