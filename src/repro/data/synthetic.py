"""Deterministic synthetic data pipelines for smoke tests + end-to-end runs.

Everything is seeded and shape-stable; the LM pipeline emits token batches
with a next-token objective, the graph pipeline emits padded GraphBatches,
the recsys pipeline emits (seq, pos, neg) triples.
"""
from __future__ import annotations

from typing import Iterator, Tuple

import numpy as np

from repro.graph.sampling import build_triplets
from repro.models.gnn import GraphBatch


def lm_batches(vocab: int, batch: int, seq: int, seed: int = 0,
               ) -> Iterator[Tuple[np.ndarray, np.ndarray]]:
    """Markov-ish token stream (compressible -> loss actually decreases)."""
    rng = np.random.default_rng(seed)
    trans = rng.integers(1, vocab, size=(256,))
    while True:
        x = np.zeros((batch, seq + 1), np.int32)
        state = rng.integers(0, 256, size=(batch,))
        for t in range(seq + 1):
            nxt = trans[state % 256]
            noise = rng.integers(1, vocab, size=(batch,))
            take_noise = rng.random(batch) < 0.15
            x[:, t] = np.where(take_noise, noise, nxt)
            state = (state * 31 + x[:, t]) % 256
        yield x[:, :-1], x[:, 1:]


def graph_batch(n_nodes: int, n_edges: int, d_feat: int, n_classes: int,
                seed: int = 0, with_coords: bool = False,
                max_triplets_per_edge: int = 4) -> GraphBatch:
    rng = np.random.default_rng(seed)
    senders = rng.integers(0, n_nodes, n_edges).astype(np.int32)
    receivers = rng.integers(0, n_nodes, n_edges).astype(np.int32)
    feat = rng.normal(size=(n_nodes, d_feat)).astype(np.float32)
    labels = rng.integers(0, n_classes, n_nodes).astype(np.int32)
    coords = rng.normal(size=(n_nodes, 3)).astype(np.float32) if with_coords else None
    tkj = tji = None
    if with_coords:
        tkj, tji = build_triplets(senders, receivers, max_triplets_per_edge, rng)
    return GraphBatch(
        node_feat=feat, senders=senders, receivers=receivers,
        edge_mask=np.ones(n_edges, bool), node_mask=np.ones(n_nodes, bool),
        labels=labels, coords=coords, triplet_kj=tkj, triplet_ji=tji)


def sasrec_batches(n_items: int, batch: int, seq_len: int, seed: int = 0,
                   ) -> Iterator[Tuple[np.ndarray, np.ndarray, np.ndarray]]:
    """User histories from a popularity-skewed item distribution."""
    rng = np.random.default_rng(seed)
    while True:
        # Zipf-ish popularity: most interactions hit few items (compressible)
        raw = rng.zipf(1.3, size=(batch, seq_len + 1))
        seq = np.minimum(raw, n_items - 1).astype(np.int32)
        x = seq[:, :-1]
        pos = seq[:, 1:]
        neg = rng.integers(1, n_items, size=pos.shape).astype(np.int32)
        yield x, pos, neg
