"""Train-step factory: loss -> grads -> AdamW, with microbatch accumulation.

``make_train_step`` builds the pjit-able pure function used by both the real
trainer (launch/train.py) and the multi-pod dry-run.  Compute/communication
overlap and FSDP reduce-scatter placement are delegated to GSPMD via the
in/out shardings chosen in repro.dist.sharding; microbatching bounds
activation memory on the giant configs.
"""
from __future__ import annotations

from typing import Any, Callable, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.dist import annotate
from repro.optim import adamw


def make_train_step(loss_fn: Callable, opt_cfg: adamw.AdamWConfig,
                    n_microbatches: int = 1):
    """loss_fn(params, *batch_parts) -> scalar.

    Batch parts must have a leading batch dim divisible by n_microbatches.
    """

    def train_step(params, opt_state, *batch):
        # pin the batch to the data axes when a mesh is installed (no-op
        # otherwise) so GSPMD never gathers inputs before the microbatch split
        batch = tuple(annotate.constrain_batch(x) for x in batch)
        if n_microbatches == 1:
            loss, grads = jax.value_and_grad(loss_fn)(params, *batch)
        else:
            def micro(i):
                parts = tuple(
                    x.reshape(n_microbatches, -1, *x.shape[1:])[i] for x in batch)
                return jax.value_and_grad(loss_fn)(params, *parts)

            def body(carry, i):
                acc_loss, acc_g = carry
                l, g = micro(i)
                return (acc_loss + l,
                        jax.tree.map(jnp.add, acc_g, g)), None

            zero_g = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (loss, grads), _ = jax.lax.scan(
                body, (jnp.float32(0), zero_g),
                jnp.arange(n_microbatches))
            loss = loss / n_microbatches
            grads = jax.tree.map(lambda g: g / n_microbatches, grads)
        params, opt_state, metrics = adamw.update(grads, opt_state, params,
                                                  opt_cfg)
        metrics = dict(metrics, loss=loss)
        return params, opt_state, metrics

    return train_step


def make_eval_step(loss_fn: Callable):
    def eval_step(params, *batch):
        return loss_fn(params, *batch)
    return eval_step
