import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                           " --xla_force_host_platform_device_count=512").strip()

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell this proves, without hardware:
  * the sharding config is coherent (GSPMD partitions the step),
  * the per-device memory fits (``compiled.memory_analysis()``),
  * and extracts the roofline terms (§Roofline) from ``cost_analysis()`` +
    the collective schedule parsed from the post-SPMD HLO.

Results are cached incrementally under benchmarks/results/dryrun/ so the
full 40-cell sweep is resumable.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch llama3-405b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod] [--force]
"""
import argparse
import json
import time
import traceback

import jax

from repro.configs import ASSIGNED, REGISTRY
from repro.launch.mesh import chips, make_production_mesh
from repro.launch.roofline import collective_bytes, roofline_terms

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                           "benchmarks", "results", "dryrun")


def run_cell(arch: str, shape: str, multi_pod: bool = False,
             allow_skipped: bool = False, verbose: bool = True) -> dict:
    from repro.launch.steps import build  # late import: after XLA_FLAGS

    spec = REGISTRY[arch]
    cell = spec.cell(shape)
    tag = f"{arch}/{shape}/{'pod2' if multi_pod else 'pod1'}"
    if cell.skip and not allow_skipped:
        return dict(arch=arch, shape=shape, multi_pod=multi_pod,
                    status="skipped", note=cell.note)
    mesh = make_production_mesh(multi_pod=multi_pod)
    t0 = time.time()
    fn, args, in_sh, out_sh = build(spec, cell, mesh)
    jitted = jax.jit(fn, in_shardings=in_sh, out_shardings=out_sh)
    lowered = jitted.lower(*args)
    t_lower = time.time() - t0
    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis() or {}
    try:
        hlo = compiled.as_text()
    except Exception:
        hlo = lowered.as_text()
    coll = collective_bytes(hlo)
    terms = roofline_terms(cost, coll, chips(mesh))

    mem_info = {}
    for attr in ("temp_size_in_bytes", "argument_size_in_bytes",
                 "output_size_in_bytes", "generated_code_size_in_bytes"):
        mem_info[attr] = int(getattr(mem, attr, 0) or 0)

    res = dict(arch=arch, shape=shape, multi_pod=multi_pod, status="ok",
               kind=cell.kind, chips=chips(mesh),
               t_lower_s=round(t_lower, 1), t_compile_s=round(t_compile, 1),
               memory=mem_info, cost=dict(
                   flops=float(cost.get("flops", 0.0)),
                   bytes_accessed=float(cost.get("bytes accessed", 0.0))),
               collectives=coll, roofline=terms, note=cell.note)
    if verbose:
        per_dev = (mem_info["argument_size_in_bytes"]
                   + mem_info["temp_size_in_bytes"]) / 1e9
        print(f"[{tag}] ok lower={t_lower:.0f}s compile={t_compile:.0f}s "
              f"mem/dev={per_dev:.2f}GB dominant={terms['dominant']} "
              f"t=({terms['t_compute']:.2e},{terms['t_memory']:.2e},"
              f"{terms['t_collective']:.2e})s")
    return res


def _cache_path(arch, shape, multi_pod):
    os.makedirs(RESULTS_DIR, exist_ok=True)
    return os.path.join(
        RESULTS_DIR, f"{arch}__{shape}__{'pod2' if multi_pod else 'pod1'}.json")


def run_all(multi_pod: bool, force: bool = False, archs=None) -> None:
    archs = archs or ASSIGNED
    for arch in archs:
        for cell in REGISTRY[arch].cells:
            path = _cache_path(arch, cell.name, multi_pod)
            if os.path.exists(path) and not force:
                print(f"[{arch}/{cell.name}] cached")
                continue
            try:
                res = run_cell(arch, cell.name, multi_pod)
            except Exception as e:  # record failures, keep sweeping
                res = dict(arch=arch, shape=cell.name, multi_pod=multi_pod,
                           status="error", error=f"{type(e).__name__}: {e}",
                           tb=traceback.format_exc()[-2000:])
                print(f"[{arch}/{cell.name}] ERROR {e}")
            with open(path, "w") as f:
                json.dump(res, f, indent=1)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--allow-full-attn-500k", action="store_true")
    args = ap.parse_args()
    if args.all:
        run_all(args.multi_pod, args.force)
        return
    res = run_cell(args.arch, args.shape, args.multi_pod,
                   allow_skipped=args.allow_full_attn_500k)
    path = _cache_path(args.arch, args.shape, args.multi_pod)
    with open(path, "w") as f:
        json.dump(res, f, indent=1)
    print(json.dumps({k: v for k, v in res.items() if k != "tb"}, indent=1))


if __name__ == "__main__":
    main()
