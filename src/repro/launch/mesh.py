"""Production mesh definitions (TPU v5e pods).

Single pod = 16x16 = 256 chips, axes ("data", "model").
Multi-pod   = 2x16x16 = 512 chips, axes ("pod", "data", "model") — the pod
axis carries data parallelism (and joins the FSDP group for archs that set
``fsdp_axes=("pod", "data")``).

Defined as functions (never module-level constants) so importing this module
never touches jax device state.
"""
from __future__ import annotations

import jax

# hardware constants used by the roofline analysis (TPU v5e)
PEAK_FLOPS_BF16 = 197e12       # per chip
HBM_BW = 819e9                 # bytes/s per chip
ICI_BW = 50e9                  # bytes/s per link


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh():
    """Whatever devices exist locally, as a 1-D ('data',) mesh (tests/CPU)."""
    n = len(jax.devices())
    return jax.make_mesh((n,), ("data",))


def make_engine_mesh(n_devices: int | None = None):
    """1-D ('shard',) mesh for edge-partitioned summarization engines.

    Uses the first ``n_devices`` local devices (all of them by default); the
    ShardedSummarizer lays one or more engine replicas on each.
    """
    import numpy as np
    from jax.sharding import Mesh
    devs = jax.devices()
    n = len(devs) if n_devices is None else n_devices
    if not 1 <= n <= len(devs):
        raise ValueError(f"need 1..{len(devs)} devices, got {n}")
    return Mesh(np.asarray(devs[:n]), ("shard",))


def chips(mesh) -> int:
    return mesh.devices.size
