"""Per-family step builders: (ArchSpec, cell, mesh) -> lowered-compile-ready.

Each builder returns ``(fn, args, in_shardings, out_shardings)`` where every
arg is a ShapeDtypeStruct (abstract init via jax.eval_shape — no allocation,
the multi-pod dry-run contract).
"""
from __future__ import annotations

import dataclasses
import os
from functools import partial
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ArchSpec, ShapeCell
from repro.dist import annotate
from repro.dist import sharding as shd
from repro.models import gnn as gnn_mod
from repro.models import sasrec as sasrec_mod
from repro.models import transformer as tfm
from repro.optim import adamw
from repro.train.step import make_train_step

# per-arch training knobs (memory-driven)
TRAIN_OVERRIDES: Dict[str, dict] = {
    "llama3-405b": dict(n_microbatches=8, moment_dtype=jnp.bfloat16),
    "internlm2-20b": dict(n_microbatches=2, moment_dtype=jnp.float32),
    "moonshot-v1-16b-a3b": dict(n_microbatches=2, moment_dtype=jnp.float32),
}


def _ns(mesh: Mesh, spec) -> Any:
    return jax.tree.map(lambda s: NamedSharding(mesh, s), spec,
                        is_leaf=lambda x: isinstance(x, P))


def _opt_cfg(arch_id: str) -> adamw.AdamWConfig:
    ov = TRAIN_OVERRIDES.get(arch_id, {})
    return adamw.AdamWConfig(moment_dtype=ov.get("moment_dtype", jnp.float32))


def _data_spec(mesh: Mesh, rank: int) -> P:
    return shd.batch_spec(mesh, rank)


# ------------------------------------------------------------------------- #
# LM family
# ------------------------------------------------------------------------- #


def build_lm(spec: ArchSpec, cell: ShapeCell, mesh: Mesh,
             smoke: bool = False):
    annotate.set_mesh(mesh)
    cfg = spec.make_smoke_config() if smoke else spec.make_config()
    inputs = cell.inputs(cfg)
    key = jax.random.key(0)
    params = jax.eval_shape(lambda k: tfm.init_transformer(cfg, k), key)
    p_specs = shd.tree_specs(params, shd.LM_RULES, mesh,
                             fsdp_axes=cfg.fsdp_axes, is_moe=cfg.moe)
    p_sh = _ns(mesh, p_specs)

    if cell.kind == "train":
        opt_cfg = _opt_cfg(spec.arch_id)
        nm = TRAIN_OVERRIDES.get(spec.arch_id, {}).get("n_microbatches", 1)
        nm = int(os.environ.get("REPRO_MICRO", nm))  # §Perf knob
        loss = partial(tfm.loss_fn, cfg=cfg)
        step = make_train_step(lambda p, t, l: loss(p, t, l), opt_cfg,
                               n_microbatches=1 if smoke else nm)
        opt = jax.eval_shape(lambda p: adamw.init(p, opt_cfg), params)
        o_specs = adamw.AdamWState(step=P(), m=p_specs, v=p_specs)
        o_sh = _ns(mesh, o_specs)
        b_sh = tuple(shd.input_sharding(mesh, inputs[k].shape,
                                        _data_spec(mesh, 2))
                     for k in ("tokens", "labels"))
        args = (params, opt, inputs["tokens"], inputs["labels"])
        in_sh = (p_sh, o_sh) + b_sh
        out_sh = (p_sh, o_sh, None)
        return step, args, in_sh, out_sh

    if cell.kind == "prefill":
        fn = partial(tfm.forward, cfg=cfg)
        tok_sh = shd.input_sharding(mesh, inputs["tokens"].shape,
                                    _data_spec(mesh, 2))
        return (lambda p, t: fn(p, t)), (params, inputs["tokens"]), \
            (p_sh, tok_sh), None

    # decode
    cb, cl = inputs["cache_batch"], inputs["cache_len"]
    cache = jax.eval_shape(lambda: tfm.init_cache(cfg, cb, cl))
    dax = shd.batch_axes(mesh)
    dax = dax if len(dax) > 1 else (dax[0] if dax else None)
    model_ok = "model" in mesh.axis_names

    def cache_spec(path_leaf_shape):
        # shard batch over data axes, cache length over model (keeps the
        # per-device KV slice bounded on the 500k/32k cells)
        rank = len(path_leaf_shape)
        if rank == 4:   # mla: [L, B, S, d]
            return P(None, dax, "model" if model_ok else None, None)
        if rank == 5:   # gqa: [L, B, Hkv, S, d]
            return P(None, dax, None, "model" if model_ok else None, None)
        return P()

    c_specs = jax.tree.map(
        lambda l: shd.guard_spec(cache_spec(l.shape), l.shape, mesh), cache)
    c_sh = _ns(mesh, c_specs)
    tok_sh = shd.input_sharding(mesh, inputs["tokens"].shape, P(dax))
    fn = partial(tfm.decode_step, cfg=cfg)
    return (lambda p, c, t: fn(p, c, t)), (params, cache, inputs["tokens"]), \
        (p_sh, c_sh, tok_sh), (None, c_sh)


# ------------------------------------------------------------------------- #
# GNN family
# ------------------------------------------------------------------------- #


def build_gnn(spec: ArchSpec, cell: ShapeCell, mesh: Mesh,
              smoke: bool = False):
    annotate.set_mesh(mesh)
    cfg = spec.make_smoke_config() if smoke else spec.make_config()
    if not smoke:
        # width the input projection to the cell's feature dim
        f = cell.inputs(cfg)["node_feat"].shape[1]
        cfg = dataclasses.replace(cfg, d_in=f)
    inputs = cell.inputs(cfg)
    key = jax.random.key(0)
    params = jax.eval_shape(lambda k: gnn_mod.init_gnn(cfg, k), key)
    p_sh = _ns(mesh, shd.tree_specs(params, shd.GNN_RULES, mesh))

    all_axes = tuple(a for a in ("pod", "data", "model") if a in mesh.axis_names)
    row = all_axes if len(all_axes) > 1 else (all_axes[0] if all_axes else None)

    field_names = list(inputs.keys())

    def to_batch(**kw):
        return gnn_mod.GraphBatch(
            node_feat=kw["node_feat"], senders=kw["senders"],
            receivers=kw["receivers"], edge_mask=kw["edge_mask"],
            node_mask=kw["node_mask"], labels=kw["labels"],
            coords=kw.get("coords"), triplet_kj=kw.get("triplet_kj"),
            triplet_ji=kw.get("triplet_ji"))

    opt_cfg = _opt_cfg(spec.arch_id)
    step = make_train_step(
        lambda p, *arrs: gnn_mod.gnn_loss(
            p, to_batch(**dict(zip(field_names, arrs))), cfg), opt_cfg)
    opt = jax.eval_shape(lambda p: adamw.init(p, opt_cfg), params)
    o_specs = adamw.AdamWState(step=P(),
                               m=shd.tree_specs(params, shd.GNN_RULES, mesh),
                               v=shd.tree_specs(params, shd.GNN_RULES, mesh))
    o_sh = _ns(mesh, o_specs)
    arr_sh = tuple(
        shd.input_sharding(mesh, inputs[n].shape,
                           P(row, *([None] * (len(inputs[n].shape) - 1))))
        for n in field_names)
    args = (params, opt) + tuple(inputs[n] for n in field_names)
    return step, args, (p_sh, o_sh) + arr_sh, (p_sh, o_sh, None)


# ------------------------------------------------------------------------- #
# recsys family
# ------------------------------------------------------------------------- #


def build_recsys(spec: ArchSpec, cell: ShapeCell, mesh: Mesh,
                 smoke: bool = False):
    cfg = spec.make_smoke_config() if smoke else spec.make_config()
    inputs = cell.inputs(cfg)
    key = jax.random.key(0)
    params = jax.eval_shape(lambda k: sasrec_mod.init_sasrec(cfg, k), key)
    p_sh = _ns(mesh, shd.tree_specs(params, shd.RECSYS_RULES, mesh))
    dspec = _data_spec(mesh, 2)

    if cell.kind == "train":
        opt_cfg = _opt_cfg(spec.arch_id)
        step = make_train_step(
            lambda p, s, po, ne: sasrec_mod.train_loss(p, s, po, ne, cfg),
            opt_cfg)
        opt = jax.eval_shape(lambda p: adamw.init(p, opt_cfg), params)
        sp = shd.tree_specs(params, shd.RECSYS_RULES, mesh)
        o_sh = _ns(mesh, adamw.AdamWState(step=P(), m=sp, v=sp))
        b_sh = tuple(shd.input_sharding(mesh, inputs[k].shape, dspec)
                     for k in ("seq", "pos", "neg"))
        args = (params, opt, inputs["seq"], inputs["pos"], inputs["neg"])
        return step, args, (p_sh, o_sh) + b_sh, (p_sh, o_sh, None)

    fn = partial(sasrec_mod.score_candidates, cfg=cfg)
    cand_sh = shd.input_sharding(
        mesh, inputs["candidates"].shape,
        P("model" if "model" in mesh.axis_names else None))
    args = (params, inputs["seq"], inputs["candidates"])
    return (lambda p, s, c: fn(p, s, c)), args, \
        (p_sh, shd.input_sharding(mesh, inputs["seq"].shape, dspec),
         cand_sh), None


# ------------------------------------------------------------------------- #
# mosso family: sharded summarization (edge-partitioned engines)
# ------------------------------------------------------------------------- #


def build_mosso(spec: ArchSpec, cell: ShapeCell, mesh: Mesh,
                smoke: bool = False):
    from repro.core.engine.state import new_state
    from repro.core.engine.trial import step_fn

    cfg = spec.make_smoke_config() if smoke else spec.make_config()
    inputs = cell.inputs(cfg)
    n_dev = int(mesh.devices.size)
    axes = tuple(mesh.axis_names)

    state1 = jax.eval_shape(lambda: new_state(cfg))
    stacked = jax.tree.map(
        lambda l: jax.ShapeDtypeStruct((n_dev,) + tuple(l.shape), l.dtype),
        state1)
    st_sh = jax.tree.map(
        lambda l: NamedSharding(mesh, P(axes, *([None] * (len(l.shape) - 1)))),
        state1)
    ch_sh = NamedSharding(mesh, P(axes))

    from jax.experimental.shard_map import shard_map

    def local_step(st, u, v, ins):
        st0 = jax.tree.map(lambda x: x[0], st)
        st1 = step_fn(st0, u[0], v[0], ins[0], cfg)
        phi = jax.lax.psum(st1.phi, axes)
        st1 = st1._replace(phi=st1.phi)  # local phi stays local
        out = jax.tree.map(lambda x: x[None], st1)
        return out, phi[None]

    dist_step = shard_map(
        local_step, mesh=mesh,
        in_specs=(jax.tree.map(lambda _: P(axes), state1), P(axes), P(axes),
                  P(axes)),
        out_specs=(jax.tree.map(lambda _: P(axes), state1), P(axes)),
        check_rep=False)

    b = cfg.batch
    args = (stacked,
            jax.ShapeDtypeStruct((n_dev, b), jnp.int32),
            jax.ShapeDtypeStruct((n_dev, b), jnp.int32),
            jax.ShapeDtypeStruct((n_dev, b), jnp.bool_))
    in_sh = (st_sh, ch_sh, ch_sh, ch_sh)
    return dist_step, args, in_sh, (st_sh, ch_sh)


BUILDERS = {"lm": build_lm, "gnn": build_gnn, "recsys": build_recsys,
            "mosso": build_mosso}


def build(spec: ArchSpec, cell: ShapeCell, mesh: Mesh, smoke: bool = False):
    return BUILDERS[spec.family](spec, cell, mesh, smoke)
