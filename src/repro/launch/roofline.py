"""Roofline-term extraction from compiled dry-run artifacts.

  compute term    = HLO_FLOPs / (chips x peak_FLOP/s)
  memory term     = HLO_bytes / (chips x HBM_bw)
  collective term = collective_bytes / (chips x link_bw)

FLOPs/bytes come from ``compiled.cost_analysis()``; collective bytes are not
reported there, so we parse the post-SPMD HLO text and sum the output-tensor
bytes of every all-gather / all-reduce / reduce-scatter / all-to-all /
collective-permute (including -start async forms).  Output-size is the
standard proxy for wire bytes (exact for all-reduce/permute, upper bound for
all-gather, lower for reduce-scatter); noted in EXPERIMENTS.md.
"""
from __future__ import annotations

import re
from typing import Dict

from repro.launch.mesh import HBM_BW, ICI_BW, PEAK_FLOPS_BF16

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for dtype, dims in _SHAPE_RE.findall(shape_str):
        b = _DTYPE_BYTES.get(dtype)
        if b is None:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * b
    return total


def collective_bytes(hlo_text: str) -> Dict[str, int]:
    """Sum output bytes per collective kind over the whole module."""
    out = {k: 0 for k in _COLLECTIVES}
    for line in hlo_text.splitlines():
        line = line.strip()
        m = re.match(r"%?[\w.\-]+\s*=\s*(\([^)]*\)|[\w\[\],{}\s]+?)\s+([\w\-]+)\(",
                     line)
        if not m:
            continue
        shape_str, op = m.group(1), m.group(2)
        for kind in _COLLECTIVES:
            if op == kind or op == kind + "-start":
                out[kind] += _shape_bytes(shape_str)
    return out


def roofline_terms(cost: dict, coll: Dict[str, int], n_chips: int) -> dict:
    flops = float(cost.get("flops", 0.0))
    byts = float(cost.get("bytes accessed", 0.0))
    cbytes = float(sum(coll.values()))
    # cost_analysis is per-partition (post-SPMD) in jax; treat as per-device
    t_compute = flops / PEAK_FLOPS_BF16
    t_memory = byts / HBM_BW
    t_collective = cbytes / ICI_BW
    dominant = max((("compute", t_compute), ("memory", t_memory),
                    ("collective", t_collective)), key=lambda kv: kv[1])[0]
    return dict(flops=flops, bytes=byts, collective_bytes=cbytes,
                t_compute=t_compute, t_memory=t_memory,
                t_collective=t_collective, dominant=dominant,
                n_chips=n_chips)


def model_flops(n_params_active: int, tokens: int) -> float:
    """MODEL_FLOPS = 6 * N * D (dense) / 6 * N_active * D (MoE)."""
    return 6.0 * n_params_active * tokens
