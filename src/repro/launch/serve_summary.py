"""Online summary service: read traffic concurrent with the write stream.

Promotes the write driver (``launch/stream.py``) and the serving pattern
of ``launch/serve.py`` into one loop over the graph workload the paper
motivates: a :class:`ShardedSummarizer` consumes the change stream chunk
by chunk while ``neighbors``/``degree``/``has_edge`` reads are answered
from flush-epoch query snapshots (:mod:`repro.serve.query`).  On the
pipelined sync-free router the snapshot intentionally trails the write
head by the one routed-but-undispatched chunk, so reads overlap the
in-flight engine stage instead of forcing a per-chunk barrier — the
reported ``epoch lag`` histogram makes that overlap visible.

``--verify`` additionally checks every sampled read against the host
ground truth of the snapshot's OWN epoch prefix (not the write head's),
i.e. the snapshot-consistency contract tests/test_query.py pins.

Usage:
  PYTHONPATH=src python -m repro.launch.serve_summary --nodes 400 \
      --reads-per-chunk 64 --verify
"""
from __future__ import annotations

import argparse
import time
from typing import Sequence

import numpy as np

from repro.core.engine import EngineConfig, ShardedSummarizer
from repro.dist.router import DEFAULT_REPLICA_EXEC, REPLICA_EXEC_MODES
from repro.launch.stream import make_stream


def serve_summary(summarizer: ShardedSummarizer, stream: Sequence,
                  reads_per_chunk: int = 64, verify: bool = False,
                  seed: int = 0) -> dict:
    """Interleave write chunks with read batches; return service stats.

    Reads are sampled from the labels streamed so far and answered from a
    fresh ``query()`` snapshot after every chunk — while the pipelined
    router still has that chunk's engine stage (and the next chunk's
    routing) in flight.  With ``verify`` each read batch is compared to
    the edge set of the snapshot's epoch prefix.
    """
    rng = np.random.default_rng(seed)
    chunk_n = summarizer.router_chunk
    n_chunks = -(-len(stream) // chunk_n)
    seen: list = []
    seen_set: set = set()
    live_after: list = []       # live edge set after chunk k (verify only)
    live: set = set()

    n_reads = 0
    t_read = 0.0
    lags: list = []
    for k in range(n_chunks):
        chunk = stream[k * chunk_n:(k + 1) * chunk_n]
        summarizer.process(chunk)
        for (u, v, ins) in chunk:
            for lab in (u, v):
                if lab not in seen_set:
                    seen_set.add(lab)
                    seen.append((lab, k + 1))   # first visible at epoch k+1
            if verify:
                e = (min(u, v), max(u, v))
                live.add(e) if ins else live.discard(e)
        if verify:
            live_after.append(frozenset(live))

        view = summarizer.query()
        lags.append(k + 1 - view.epoch)
        # only labels the snapshot's epoch has seen are queryable on it
        pool = [lab for (lab, ep) in seen if ep <= view.epoch]
        if not pool:
            continue
        labs = [pool[i] for i in
                rng.integers(0, len(pool), reads_per_chunk)]
        pairs = list(zip(labs, labs[::-1]))
        t0 = time.perf_counter()
        nbrs = view.neighbors_batch(labs)
        degs = view.degree_batch(labs)
        present = [view.has_edge(u, v) if u != v else False
                   for (u, v) in pairs[:8]]
        t_read += time.perf_counter() - t0
        n_reads += len(labs) * 2 + len(present)

        if verify:
            truth = live_after[view.epoch - 1] if view.epoch else frozenset()
            adj: dict = {}
            for (u, v) in truth:
                adj.setdefault(u, set()).add(v)
                adj.setdefault(v, set()).add(u)
            for lab, s, d in zip(labs, nbrs, degs):
                want = adj.get(lab, set())
                assert s == want, f"epoch {view.epoch} neighbors({lab!r})"
                assert d == len(want)
            for (u, v), p in zip(pairs, present):
                want = (min(u, v), max(u, v)) in truth
                assert p == want, f"epoch {view.epoch} has_edge({u!r},{v!r})"

    summarizer.flush()
    final = summarizer.query()
    assert final.epoch == n_chunks
    if verify:
        labs = [lab for (lab, _) in seen]
        truth = live_after[-1] if live_after else frozenset()
        adj = {}
        for (u, v) in truth:
            adj.setdefault(u, set()).add(v)
            adj.setdefault(v, set()).add(u)
        for lab, s in zip(labs, final.neighbors_batch(labs)):
            assert s == adj.get(lab, set()), f"final neighbors({lab!r})"

    return dict(chunks=n_chunks, changes=len(stream), reads=n_reads,
                us_per_read=1e6 * t_read / max(n_reads, 1),
                epoch_lags=lags, max_lag=max(lags, default=0),
                reads_overlapped_writes=any(l > 0 for l in lags),
                final_epoch=final.epoch, phi=summarizer.phi,
                num_edges=summarizer.num_edges, verified=bool(verify))


def main() -> None:
    dflt = EngineConfig()
    ap = argparse.ArgumentParser()
    ap.add_argument("--graph", choices=["ba", "copying"], default="ba")
    ap.add_argument("--nodes", type=int, default=400)
    ap.add_argument("--deg", type=int, default=4)
    ap.add_argument("--beta", type=float, default=0.7)
    ap.add_argument("--fully-dynamic", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--shards", type=int, default=None)
    ap.add_argument("--router-chunk", type=int, default=256)
    ap.add_argument("--no-pipeline", action="store_true",
                    help="serial route/engine dispatch: every snapshot "
                         "then sits exactly at the write head (lag 0)")
    ap.add_argument("--replica-exec", choices=list(REPLICA_EXEC_MODES),
                    default=DEFAULT_REPLICA_EXEC)
    ap.add_argument("--reads-per-chunk", type=int, default=64)
    ap.add_argument("--verify", action="store_true",
                    help="differentially check every sampled read against "
                         "the snapshot epoch's host ground truth")
    ap.add_argument("--c", type=int, default=dflt.c)
    ap.add_argument("--escape", type=float, default=dflt.escape)
    ap.add_argument("--batch", type=int, default=dflt.batch)
    args = ap.parse_args()

    stream = make_stream(args.graph, args.nodes, args.deg, args.beta,
                         args.fully_dynamic, args.seed)
    n_cap = 1 << max(8, (args.nodes * 2).bit_length())
    m_cap = 1 << max(10, (len(stream) * 2).bit_length())
    ss = ShardedSummarizer(
        EngineConfig(n_cap=n_cap, m_cap=m_cap, c=args.c, escape=args.escape,
                     batch=args.batch),
        n_shards=args.shards, router_chunk=args.router_chunk,
        pipeline=not args.no_pipeline, replica_exec=args.replica_exec)
    print(f"stream: {len(stream)} changes; shards={ss.n_shards} "
          f"pipeline={ss.pipeline}")
    t0 = time.time()
    out = serve_summary(ss, stream, reads_per_chunk=args.reads_per_chunk,
                        verify=args.verify, seed=args.seed)
    el = time.time() - t0
    print(f"served {out['reads']} reads over {out['chunks']} write chunks "
          f"({out['us_per_read']:.0f} us/read, max epoch lag "
          f"{out['max_lag']}, overlapped={out['reads_overlapped_writes']})")
    print(f"phi={out['phi']} |E|={out['num_edges']} "
          f"verified={out['verified']}  total {el:.1f}s "
          f"({1e6 * el / len(stream):.0f} us/change incl. reads)")


if __name__ == "__main__":
    main()
