"""MoSSo streaming driver: summarize a dynamic graph stream end to end.

Runs the faithful reference (Tier A), the batched engine (Tier B), or the
edge-partitioned sharded engine over a synthetic or file-based stream,
reporting phi, the compression ratio (Eq. 3), and per-change timing — the
paper's any-time workload as a CLI.  The sharded engine streams batches
through the device-side router by default (``--routing device``); pass
``--routing host`` to drive the same shards through host bucketing, the
differential reference path.

With ``--checkpoint-dir`` the batched/sharded engines run crash-consistent:
every dispatch chunk is write-ahead journaled, an epoch checkpoint lands
every ``--checkpoint-every`` chunks, and a failed chunk abandons the live
summarizer, restores the latest valid epoch, replays the journal tail and
resumes (``repro.ft.resilience.run_stream_with_recovery``; retries are
reported as ``stream_retries`` in the final stats).  ``--resume`` recovers
from the directory before processing, so a killed run continues from its
last journaled chunk instead of starting over.

Usage:
  PYTHONPATH=src python -m repro.launch.stream --algo mosso --nodes 2000 \
      --edges 8000 --engine reference
  PYTHONPATH=src python -m repro.launch.stream --engine batched --batch 64
  PYTHONPATH=src python -m repro.launch.stream --engine sharded --shards 2 \
      --routing device --router-chunk 1024
  PYTHONPATH=src python -m repro.launch.stream --engine sharded \
      --checkpoint-dir /tmp/mosso-ckpt --checkpoint-every 8 --resume
"""
from __future__ import annotations

import argparse
import time

from repro.core.engine import (BatchedSummarizer, EngineConfig,
                               ShardedSummarizer)
from repro.core.engine.state import OBJECTIVES, PROPOSALS
from repro.core.reference import ALGORITHMS, WeightedDynamicSummary
from repro.dist.router import DEFAULT_REPLICA_EXEC, REPLICA_EXEC_MODES
from repro.graph.streams import (barabasi_albert_edges, copying_model_edges,
                                 edges_to_fully_dynamic_stream,
                                 edges_to_insertion_stream)


def make_stream(kind: str, nodes: int, edges_per_node: int, beta: float,
                fully_dynamic: bool, seed: int):
    if kind == "copying":
        edges = copying_model_edges(nodes, edges_per_node, beta, seed)
    else:
        edges = barabasi_albert_edges(nodes, edges_per_node, seed)
    if fully_dynamic:
        return edges_to_fully_dynamic_stream(edges, seed=seed)
    return edges_to_insertion_stream(edges, seed=seed)


def main() -> None:
    # search/batch defaults come FROM EngineConfig, so the CLI, tests, and
    # benchmarks run the same configuration by construction (drifting
    # literals here once shipped c=32/escape=0.2/batch=64 against the
    # engine's 20/0.3/32)
    dflt = EngineConfig()
    ap = argparse.ArgumentParser()
    ap.add_argument("--engine", choices=["reference", "batched", "sharded"],
                    default="reference")
    ap.add_argument("--shards", type=int, default=None,
                    help="sharded: logical partitions (default: one/device)")
    ap.add_argument("--routing", choices=["device", "host"], default="device",
                    help="sharded: device-side router or host bucketing")
    ap.add_argument("--router-chunk", type=int, default=1024,
                    help="sharded: changes per routed dispatch")
    ap.add_argument("--lane-cap", type=int, default=None,
                    help="sharded: per (source, shard) router lane capacity")
    ap.add_argument("--max-drain-rounds", type=int, default=None,
                    help="sharded: on-device overflow drain round budget "
                         "(default: enough to guarantee full delivery, "
                         "which elides the per-chunk watermark sync)")
    ap.add_argument("--chunk-sync", action="store_true",
                    help="sharded: force the per-chunk watermark fetch even "
                         "when delivery is statically guaranteed (measures "
                         "the sync-elision gap)")
    ap.add_argument("--no-pipeline", action="store_true",
                    help="sharded: dispatch route and engine stages "
                         "serially per chunk instead of overlapping chunk "
                         "k+1's routing with chunk k's engine rounds "
                         "(measures the pipeline gap; results are "
                         "bit-identical)")
    ap.add_argument("--replica-exec", choices=list(REPLICA_EXEC_MODES),
                    default=DEFAULT_REPLICA_EXEC,
                    help="sharded: lay the per-device shard replicas out "
                         "as one vmapped program (default) or a "
                         "serializing lax.map (the differential "
                         "reference; results are bit-identical)")
    ap.add_argument("--algo", choices=list(ALGORITHMS), default="mosso")
    ap.add_argument("--graph", choices=["ba", "copying"], default="ba")
    ap.add_argument("--nodes", type=int, default=2000)
    ap.add_argument("--deg", type=int, default=4)
    ap.add_argument("--beta", type=float, default=0.7)
    ap.add_argument("--fully-dynamic", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--c", type=int, default=dflt.c)
    ap.add_argument("--escape", type=float, default=dflt.escape)
    ap.add_argument("--batch", type=int, default=dflt.batch)
    # policy triple: defaults from EngineConfig (which resolves the
    # REPRO_PROPOSAL/REPRO_OBJECTIVE env vars), same no-drift contract
    ap.add_argument("--proposal", choices=list(PROPOSALS),
                    default=dflt.proposal,
                    help="candidate scheme (batched/sharded engines; the "
                         "reference analog is --algo mosso vs --algo mags)")
    ap.add_argument("--objective", choices=list(OBJECTIVES),
                    default=dflt.objective,
                    help="move-scoring objective (all engines)")
    ap.add_argument("--weight-levels", type=int, default=dflt.weight_levels,
                    help="weighted objective: node weights 1 + hash % N "
                         "(0/1 = uniform)")
    ap.add_argument("--checkpoint-dir", default=None,
                    help="batched/sharded: crash-consistent mode — "
                         "write-ahead journal every dispatch chunk and "
                         "checkpoint epochs into this directory")
    ap.add_argument("--checkpoint-every", type=int, default=16,
                    help="chunks between epoch checkpoints "
                         "(with --checkpoint-dir)")
    ap.add_argument("--resume", action="store_true",
                    help="recover from --checkpoint-dir (last valid epoch "
                         "+ journal replay) before processing")
    ap.add_argument("--max-failures", type=int, default=3,
                    help="failed chunks tolerated before giving up "
                         "(with --checkpoint-dir)")
    args = ap.parse_args()
    if args.checkpoint_dir and args.engine == "reference":
        ap.error("--checkpoint-dir requires --engine batched or sharded "
                 "(the reference tier has no checkpoint closure)")

    stream = make_stream(args.graph, args.nodes, args.deg, args.beta,
                         args.fully_dynamic, args.seed)
    print(f"stream: {len(stream)} changes")
    t0 = time.time()
    if args.engine == "reference":
        algo = ALGORITHMS[args.algo](seed=args.seed)
        if args.objective == "weighted":
            # the driver hooks are summary-agnostic: swap in the weighted
            # host state machine before any change is processed
            algo.s = WeightedDynamicSummary(weight_levels=args.weight_levels)
        if hasattr(algo, "c"):
            algo.c = args.c
        if hasattr(algo, "escape"):
            algo.escape = args.escape
        algo.run(stream)
        phi, m = algo.s.phi, algo.s.num_edges
        extra = f"trials={algo.stats.trials} accepted={algo.stats.accepted}"
    elif args.engine == "batched":
        n_cap = 1 << max(8, (args.nodes * 2).bit_length())
        m_cap = 1 << max(10, (len(stream) * 2).bit_length())
        cfg = EngineConfig(
            n_cap=n_cap, m_cap=m_cap, c=args.c, escape=args.escape,
            batch=args.batch, proposal=args.proposal,
            objective=args.objective, weight_levels=args.weight_levels)
        if args.checkpoint_dir:
            from repro.ft.resilience import run_stream_with_recovery
            bs = run_stream_with_recovery(
                lambda: BatchedSummarizer(
                    cfg, checkpoint_dir=args.checkpoint_dir),
                stream, args.checkpoint_dir,
                ckpt_every=args.checkpoint_every, resume=args.resume,
                max_failures=args.max_failures)
        else:
            bs = BatchedSummarizer(cfg).run(stream)
        phi, m = bs.phi, bs.num_edges
        extra = str(bs.stats())
    else:
        # per-shard caps: vertex-cut replication means n_cap budgets more
        # than |V| / n_shards (src/repro/dist/README.md)
        n_cap = 1 << max(8, (args.nodes * 2).bit_length())
        m_cap = 1 << max(10, (len(stream) * 2).bit_length())
        cfg = EngineConfig(n_cap=n_cap, m_cap=m_cap, c=args.c,
                           escape=args.escape, batch=args.batch,
                           proposal=args.proposal, objective=args.objective,
                           weight_levels=args.weight_levels)

        def make_sharded():
            return ShardedSummarizer(
                cfg, n_shards=args.shards, routing=args.routing,
                router_chunk=args.router_chunk, lane_cap=args.lane_cap,
                max_drain_rounds=args.max_drain_rounds,
                chunk_sync=args.chunk_sync, pipeline=not args.no_pipeline,
                replica_exec=args.replica_exec,
                checkpoint_dir=args.checkpoint_dir)

        if args.checkpoint_dir:
            from repro.ft.resilience import run_stream_with_recovery
            ss = run_stream_with_recovery(
                make_sharded, stream, args.checkpoint_dir,
                ckpt_every=args.checkpoint_every, resume=args.resume,
                max_failures=args.max_failures)
        else:
            ss = make_sharded()
            if args.routing == "device":
                print(f"router: lane_cap={ss.lane_cap} "
                      f"max_drain_rounds={ss.max_drain_rounds} "
                      f"sync_free={ss.sync_free} pipeline={ss.pipeline} "
                      f"replica_exec={ss.replica_exec}")
            ss.run(stream)
        phi, m = ss.phi, ss.num_edges
        extra = str(ss.stats())
    el = time.time() - t0
    print(f"phi={phi} |E|={m} compression_ratio={phi/max(m,1):.4f}")
    print(f"total {el:.1f}s ({1e6*el/len(stream):.0f} us/change)  {extra}")


if __name__ == "__main__":
    main()
