"""Serving driver: batched decode with a KV cache on the local mesh.

Runs a real (smoke-scale) LM: prefill a prompt batch, then decode N tokens
per request — the serving path the decode_32k / long_500k dry-run cells
lower at production scale.

Usage:
  PYTHONPATH=src python -m repro.launch.serve --arch minicpm3-4b --tokens 32
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import REGISTRY
from repro.models import transformer as tfm


def serve(arch: str, batch: int = 4, prompt_len: int = 16,
          gen_tokens: int = 32, seed: int = 0) -> dict:
    spec = REGISTRY[arch]
    assert spec.family == "lm", "serve.py drives LM archs"
    cfg = spec.make_smoke_config()
    params = tfm.init_transformer(cfg, jax.random.key(seed))
    prompt = jax.random.randint(jax.random.key(seed + 1),
                                (batch, prompt_len), 0, cfg.vocab)
    cache = tfm.init_cache(cfg, batch, prompt_len + gen_tokens)
    step = jax.jit(lambda p, c, t: tfm.decode_step(p, c, t, cfg))

    # prefill via the decode path (teacher forcing the prompt)
    t0 = time.time()
    for t in range(prompt_len):
        logits, cache = step(params, cache, prompt[:, t])
    prefill_s = time.time() - t0

    toks = []
    t0 = time.time()
    tok = jnp.argmax(logits, axis=-1)
    for _ in range(gen_tokens):
        toks.append(tok)
        logits, cache = step(params, cache, tok)
        tok = jnp.argmax(logits, axis=-1)
    jax.block_until_ready(logits)
    decode_s = time.time() - t0
    out = jnp.stack(toks, axis=1)
    return dict(tokens=out, prefill_s=prefill_s, decode_s=decode_s,
                ms_per_token=1e3 * decode_s / gen_tokens)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="minicpm3-4b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--tokens", type=int, default=32)
    args = ap.parse_args()
    out = serve(args.arch, args.batch, args.prompt_len, args.tokens)
    print(f"generated {out['tokens'].shape} tokens; "
          f"prefill {out['prefill_s']:.2f}s, "
          f"{out['ms_per_token']:.1f} ms/token decode")


if __name__ == "__main__":
    main()
