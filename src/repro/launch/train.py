"""End-to-end trainer: real steps on the local mesh, checkpoint/restart.

The production path (multi-pod mesh) is exercised by dryrun.py; this driver
runs *actual* training for any arch at smoke-or-custom scale on the local
devices — used by examples/train_lm_100m.py and the integration tests.

Fault-tolerance wiring: atomic checkpoints every ``ckpt_every`` steps, and a
crash-equivalent restart path (restore latest + continue) — see
repro.ft.resilience for the retry loop used on fleets.

Usage:
  PYTHONPATH=src python -m repro.launch.train --arch granite-moe-3b-a800m \
      --smoke --steps 50 [--ckpt-dir /tmp/ckpt]
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import checkpointer
from repro.configs import REGISTRY
from repro.data.synthetic import graph_batch, lm_batches, sasrec_batches
from repro.models import gnn as gnn_mod
from repro.models import sasrec as sasrec_mod
from repro.models import transformer as tfm
from repro.optim import adamw
from repro.train.step import make_train_step


def _build_smoke(arch: str, batch: int, seq: int):
    spec = REGISTRY[arch]
    cfg = spec.make_smoke_config()
    key = jax.random.key(0)
    opt_cfg = adamw.AdamWConfig(lr=1e-3, warmup_steps=10, total_steps=1000)
    if spec.family == "lm":
        params = tfm.init_transformer(cfg, key)
        step = jax.jit(make_train_step(
            lambda p, t, l: tfm.loss_fn(p, t, l, cfg), opt_cfg))
        data = lm_batches(cfg.vocab, batch, seq)
        batches = ((jnp.asarray(x), jnp.asarray(y)) for x, y in data)
    elif spec.family == "gnn":
        params = gnn_mod.init_gnn(cfg, key)
        g = jax.tree.map(jnp.asarray, graph_batch(
            64, 256, cfg.d_in, cfg.n_classes, seed=0,
            with_coords=cfg.arch in ("egnn", "dimenet")))
        step = jax.jit(make_train_step(
            lambda p, gb: gnn_mod.gnn_loss(p, gb, cfg), opt_cfg))
        batches = iter(lambda: (g,), None)
    elif spec.family == "recsys":
        params = sasrec_mod.init_sasrec(cfg, key)
        step = jax.jit(make_train_step(
            lambda p, s, po, ne: sasrec_mod.train_loss(p, s, po, ne, cfg),
            opt_cfg))
        data = sasrec_batches(cfg.n_items, batch, cfg.seq_len)
        batches = (tuple(map(jnp.asarray, b)) for b in data)
    else:
        raise ValueError(f"train.py does not handle family {spec.family}; "
                         f"use launch/stream.py for mosso")
    opt = adamw.init(params, opt_cfg)
    return params, opt, step, batches


def train(arch: str, steps: int, batch: int = 8, seq: int = 64,
          ckpt_dir: str | None = None, ckpt_every: int = 25,
          log_every: int = 10) -> dict:
    params, opt, step, batches = _build_smoke(arch, batch, seq)
    start = 0
    if ckpt_dir:
        latest = checkpointer.latest_step(ckpt_dir)
        if latest is not None:
            params = checkpointer.restore(ckpt_dir, latest, params)
            opt = checkpointer.restore(ckpt_dir + "/opt", latest, opt)
            start = latest
            print(f"restored step {latest}")
    losses = []
    t0 = time.time()
    for i in range(start, steps):
        b = next(batches)
        params, opt, metrics = step(params, opt, *b)
        losses.append(float(metrics["loss"]))
        if log_every and (i + 1) % log_every == 0:
            print(f"step {i+1}: loss={losses[-1]:.4f} "
                  f"gnorm={float(metrics['grad_norm']):.3f} "
                  f"({(time.time()-t0)/max(1,i+1-start)*1e3:.0f} ms/step)")
        if ckpt_dir and (i + 1) % ckpt_every == 0:
            checkpointer.save(ckpt_dir, i + 1, params)
            checkpointer.save(ckpt_dir + "/opt", i + 1, opt)
    return dict(first_loss=losses[0] if losses else None,
                last_loss=losses[-1] if losses else None,
                losses=losses)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--ckpt-dir")
    ap.add_argument("--smoke", action="store_true", help="(default mode)")
    args = ap.parse_args()
    out = train(args.arch, args.steps, args.batch, args.seq, args.ckpt_dir)
    print(f"loss {out['first_loss']:.4f} -> {out['last_loss']:.4f}")


if __name__ == "__main__":
    main()
