"""Primitive state transitions of the batched engine (Tier B).

Everything here is jit-compatible and mirrors, in fixed shapes, what
:mod:`repro.core.reference.dynamic_summary` does with Python dicts:

* ``insert_edge`` / ``delete_edge``   — one stream change,
* ``delta_phi_move``                  — closed-form objective change of a move,
* ``apply_move``                      — commit an accepted move,
* ``recompute_phi``                   — fold over the E_AB table (tests).

The encoding itself (P / C+ / C-) is a *derived view* of ``(E_AB, sizes)``
via the optimal-encoding rule — the engine never materializes it on device,
which is exactly why moves only need count arithmetic (cf. "Updating Optimal
Encoding", Sect. 3.6.3).

**Predication contract.**  Every state-mutating op takes an ``ok``
predicate and lowers to *masked writes*: the op computes its (constant
number of) destination slots as usual and, when ``~ok``, writes each
slot's existing contents back — a structural no-op, bit-identical to not
having called the op at all.  Indices are sanitized at op entry
(``jnp.where(ok, u, 0)``) so masked calls with padded/garbage inputs stay
in bounds.  This is what lets ``trial.py`` lower Alg. 1 without a single
``lax.cond`` and what makes the step ``jax.vmap``-able over shard
replicas at no both-branches penalty (``repro/dist/router.py``).
"""
from __future__ import annotations

from functools import partial
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.core.engine.hashtable import (ht_add, ht_delete, ht_lookup,
                                         ht_lookup_batch, ht_set)
from repro.core.engine.state import NO_CLUSTER, EngineConfig, EngineState

# --------------------------------------------------------------------------- #
# small math helpers
# --------------------------------------------------------------------------- #


def cost(e: jax.Array, t: jax.Array) -> jax.Array:
    """Optimal per-pair encoding cost min(E, T-E+1), 0 when E==0 (int32)."""
    return jnp.where(e <= 0, 0, jnp.minimum(e, t - e + 1)).astype(jnp.int32)


def tri(n: jax.Array) -> jax.Array:
    return (n * (n - 1)) // 2


def t_of(sa: jax.Array, sb: jax.Array, same: jax.Array) -> jax.Array:
    return jnp.where(same, tri(sa), sa * sb)


def mixhash(x: jax.Array) -> jax.Array:
    """Node hash for min-hash clustering (non-negative int32, never the
    ``NO_CLUSTER`` sentinel).

    Masks with ``0x7FFFFFFF`` to keep the full 31-bit id space — an earlier
    ``0x7FFFFFFE`` mask cleared the low bit, halving the cluster-id space
    and doubling spurious CP(y) collisions — and remaps the single value
    that would collide with ``NO_CLUSTER``.
    """
    h = x.astype(jnp.uint32) * jnp.uint32(0x9E3779B9)
    h = h ^ (h >> 16)
    h = h * jnp.uint32(0x85EBCA6B)
    h = h ^ (h >> 13)
    h = (h & jnp.uint32(0x7FFFFFFF)).astype(jnp.int32)
    return jnp.where(h == NO_CLUSTER, jnp.int32(0x7FFFFFFE), h)


def rnd_u32(seed: jax.Array, ctr: jax.Array) -> jax.Array:
    """Counter-based splitmix32 PRNG (cheap, deterministic, jit-friendly)."""
    x = seed.astype(jnp.uint32) + ctr.astype(jnp.uint32) * jnp.uint32(0x9E3779B9)
    x = (x ^ (x >> 16)) * jnp.uint32(0x21F0AAAD)
    x = (x ^ (x >> 15)) * jnp.uint32(0x735A2D97)
    return x ^ (x >> 15)


def rnd_u01(seed: jax.Array, ctr: jax.Array) -> jax.Array:
    return rnd_u32(seed, ctr).astype(jnp.float32) / jnp.float32(4294967296.0)


def _mulhi_u32(a: jax.Array, b: jax.Array) -> jax.Array:
    """High 32 bits of the 64-bit product a*b, in pure uint32 arithmetic
    (jax disables uint64 without the x64 flag)."""
    a = a.astype(jnp.uint32)
    b = b.astype(jnp.uint32)
    a0, a1 = a & jnp.uint32(0xFFFF), a >> 16
    b0, b1 = b & jnp.uint32(0xFFFF), b >> 16
    lo = a0 * b0
    mid1 = a1 * b0 + (lo >> 16)
    mid2 = a0 * b1 + (mid1 & jnp.uint32(0xFFFF))
    return a1 * b1 + (mid1 >> 16) + (mid2 >> 16)


def rnd_below(seed: jax.Array, ctr: jax.Array, n: jax.Array) -> jax.Array:
    """Uniform int in [0, max(n,1)) via Lemire's multiply-shift.

    ``(u64(x) * n) >> 32`` maps the 32-bit draw onto ``[0, n)`` with bias
    at most ``n / 2**32`` per value — unlike ``x % n``, which skews toward
    small indices by up to ``n / 2**32 * n`` in aggregate and visibly
    distorts uniform-neighbor sampling (paper Thm. 1-3) for non-power-of-2
    degrees.
    """
    return _mulhi_u32(rnd_u32(seed, ctr),
                      jnp.maximum(n, 1).astype(jnp.uint32)).astype(jnp.int32)


def canon(a: jax.Array, b: jax.Array) -> Tuple[jax.Array, jax.Array]:
    return jnp.minimum(a, b), jnp.maximum(a, b)


# --------------------------------------------------------------------------- #
# weighted-objective quantities
# --------------------------------------------------------------------------- #
#
# The "weighted" objective scores phi_w = |P| + sum_{C+} w(u)w(v)
# + sum_{C-} w(u)w(v): a P entry still costs 1, but each correction costs
# its pair weight (utility-weighted summarization, arxiv 2006.08949).  The
# optimal per-pair rule generalizes VERBATIM: with W_AB the weight of live
# edges and TW_AB the weight of all member pairs, the cheaper of
# "corrections only" (W) and "superedge + negative corrections"
# (1 + TW - W) is exactly ``cost(W, TW)``, and uniform weights give
# W == E, TW == T — bit-identical to the exact objective.


def node_weight(u: jax.Array, cfg: EngineConfig) -> jax.Array:
    """w(u) = 1 + (hash(u) % weight_levels); all-ones when levels <= 1.

    Hashed from the node id so weights need no storage or I/O plumbing.
    ``repro.core.reference.weights.host_node_weight`` is the bit-exact
    host mirror — keep them in sync.
    """
    u = jnp.asarray(u)
    if cfg.weight_levels <= 1:
        return jnp.ones(u.shape, jnp.int32)
    h = rnd_u32(u.astype(jnp.uint32), jnp.uint32(0x5EED))
    return (1 + (h % jnp.uint32(cfg.weight_levels))).astype(jnp.int32)


def wtri(sw: jax.Array, sq: jax.Array) -> jax.Array:
    """TW of a self-pair: sum over unordered member pairs of w(u)w(v)
    = (SW^2 - SQ) / 2; equals ``tri(s)`` under uniform weights."""
    return (sw * sw - sq) // 2


def wt_of(st: EngineState, a: jax.Array, b: jax.Array,
          same: jax.Array) -> jax.Array:
    """TW_AB from the per-supernode weight sums (weighted objective)."""
    return jnp.where(same, wtri(st.wsum[a], st.wsq[a]),
                     st.wsum[a] * st.wsum[b])


# --------------------------------------------------------------------------- #
# supernode-pair count + SN adjacency maintenance
# --------------------------------------------------------------------------- #


def _sn_insert(st: EngineState, x: jax.Array, y: jax.Array,
               ok) -> EngineState:
    """Append y to SN(x)'s slot list (masked write under ``~ok``)."""
    i = st.sndeg[x]
    return st._replace(
        snadj=ht_set(st.snadj, x, i, y, ok=ok),
        snpos=ht_set(st.snpos, x, y, i, ok=ok),
        sndeg=st.sndeg.at[x].add(jnp.where(ok, 1, 0)),
    )


def _sn_remove(st: EngineState, x: jax.Array, y: jax.Array,
               ok) -> EngineState:
    """Swap-delete y from SN(x)'s slot list (masked write under ``~ok``)."""
    i = ht_lookup(st.snpos, x, y)
    last = st.sndeg[x] - 1
    w = ht_lookup(st.snadj, x, last)
    snadj = ht_set(st.snadj, x, i, w, ok=ok)
    snpos = ht_set(st.snpos, x, w, i, ok=ok)
    snadj = ht_delete(snadj, x, last, ok=ok)
    snpos = ht_delete(snpos, x, y, ok=ok)
    return st._replace(snadj=snadj, snpos=snpos,
                       sndeg=st.sndeg.at[x].add(jnp.where(ok, -1, 0)))


def pair_count_add(st: EngineState, a: jax.Array, b: jax.Array,
                   delta: jax.Array, ok=True) -> EngineState:
    """E_AB += delta, maintaining the SN slot lists on 0<->nonzero edges.

    Cond-free: the 0<->nonzero transition predicates gate masked
    ``_sn_insert``/``_sn_remove`` calls instead of branching.
    """
    ca, cb = canon(a, b)
    eab, new = ht_add(st.eab, ca, cb, delta, remove_if_zero=True, ok=ok)
    old = new - delta
    st = st._replace(eab=eab)
    created = ok & (old == 0) & (new != 0)
    removed = ok & (new == 0) & (old != 0)

    st = _sn_insert(st, ca, cb, created)
    st = _sn_insert(st, cb, ca, created & (ca != cb))
    st = _sn_remove(st, ca, cb, removed)
    st = _sn_remove(st, cb, ca, removed & (ca != cb))
    return st


def pair_weight_add(st: EngineState, a: jax.Array, b: jax.Array,
                    delta: jax.Array, ok=True) -> EngineState:
    """W_AB += delta (weighted objective only).

    No SN side effects: weights are positive, so W_AB hits zero exactly
    when E_AB does and ``pair_count_add``'s transitions own the slot
    lists; this table only has to agree on liveness, which
    ``remove_if_zero`` preserves.
    """
    ca, cb = canon(a, b)
    weab, _ = ht_add(st.weab, ca, cb, delta, remove_if_zero=True, ok=ok)
    return st._replace(weab=weab)


# --------------------------------------------------------------------------- #
# nodes and edges
# --------------------------------------------------------------------------- #


def ensure_node(st: EngineState, u: jax.Array, cfg: EngineConfig,
                ok=True) -> EngineState:
    """Allocate a singleton supernode for u if unseen (masked under ~ok)."""
    need = ok & (st.n2s[u] < 0)
    top = st.free_top - 1
    sid = st.free[jnp.maximum(top, 0)]
    st = st._replace(
        n2s=st.n2s.at[u].set(jnp.where(need, sid, st.n2s[u])),
        ssize=st.ssize.at[sid].set(jnp.where(need, 1, st.ssize[sid])),
        free_top=jnp.where(need, top, st.free_top),
    )
    if cfg.objective == "weighted":
        w = node_weight(u, cfg)
        st = st._replace(
            wsum=st.wsum.at[sid].set(jnp.where(need, w, st.wsum[sid])),
            wsq=st.wsq.at[sid].set(jnp.where(need, w * w, st.wsq[sid])))
    return st


def _adj_append(st: EngineState, u: jax.Array, v: jax.Array,
                ok) -> EngineState:
    i = st.deg[u]
    return st._replace(
        adj=ht_set(st.adj, u, i, v, ok=ok),
        epos=ht_set(st.epos, u, v, i, ok=ok),
        deg=st.deg.at[u].add(jnp.where(ok, 1, 0)),
    )


def _adj_remove(st: EngineState, u: jax.Array, v: jax.Array,
                ok) -> EngineState:
    i = ht_lookup(st.epos, u, v)
    last = st.deg[u] - 1
    w = ht_lookup(st.adj, u, last)
    adj = ht_set(st.adj, u, i, w, ok=ok)
    epos = ht_set(st.epos, u, w, i, ok=ok)
    adj = ht_delete(adj, u, last, ok=ok)
    epos = ht_delete(epos, u, v, ok=ok)
    return st._replace(adj=adj, epos=epos,
                       deg=st.deg.at[u].add(jnp.where(ok, -1, 0)))


def neighbor_slots(st: EngineState, y: jax.Array, d_cap: int,
                   ) -> Tuple[jax.Array, jax.Array]:
    """First min(deg, d_cap) neighbors of y (fixed-shape gather)."""
    idx = jnp.arange(d_cap, dtype=jnp.int32)
    valid = idx < st.deg[y]
    nbrs = ht_lookup_batch(st.adj, jnp.full((d_cap,), y, jnp.int32), idx,
                           default=-1)
    return jnp.where(valid, nbrs, -1), valid


def _minh_recompute(st: EngineState, u: jax.Array, d_cap: int) -> jax.Array:
    """minh(u) = min hash over (up to d_cap) current neighbors.

    Exact for deg <= d_cap; a uniform-ish subset otherwise (swap-deletes
    shuffle slot order) — deviation #1 documented in DESIGN.md.
    """
    nbrs, valid = neighbor_slots(st, u, d_cap)
    hs = jnp.where(valid, mixhash(nbrs), NO_CLUSTER)
    return jnp.min(hs).astype(jnp.int32)


def insert_edge(st: EngineState, u: jax.Array, v: jax.Array,
                cfg: EngineConfig, ok=True) -> EngineState:
    u = jnp.where(ok, u, 0)
    v = jnp.where(ok, v, 0)
    st = ensure_node(st, u, cfg, ok)
    st = ensure_node(st, v, cfg, ok)
    a, b = st.n2s[u], st.n2s[v]
    ca, cb = canon(a, b)
    if cfg.objective == "weighted":
        wuv = node_weight(u, cfg) * node_weight(v, cfg)
        w = ht_lookup(st.weab, ca, cb)
        tw = wt_of(st, a, b, a == b)
        st = st._replace(
            phi=st.phi + jnp.where(ok, cost(w + wuv, tw) - cost(w, tw), 0))
        st = pair_weight_add(st, a, b, wuv, ok)
    else:
        e = ht_lookup(st.eab, ca, cb)
        t = t_of(st.ssize[a], st.ssize[b], a == b)
        st = st._replace(
            phi=st.phi + jnp.where(ok, cost(e + 1, t) - cost(e, t), 0))
    st = pair_count_add(st, a, b, jnp.int32(1), ok)
    st = _adj_append(st, u, v, ok)
    st = _adj_append(st, v, u, ok)
    # min with INT32_MAX is the identity, so a masked call leaves minh alone
    no_op = jnp.int32(0x7FFFFFFF)
    minh = (st.minh.at[u].min(jnp.where(ok, mixhash(v), no_op))
            .at[v].min(jnp.where(ok, mixhash(u), no_op)))
    return st._replace(minh=minh,
                       num_edges=st.num_edges + jnp.where(ok, 1, 0))


def delete_edge(st: EngineState, u: jax.Array, v: jax.Array,
                cfg: EngineConfig, ok=True) -> EngineState:
    d_cap = cfg.d_cap
    u = jnp.where(ok, u, 0)
    v = jnp.where(ok, v, 0)
    a, b = st.n2s[u], st.n2s[v]
    ca, cb = canon(a, b)
    if cfg.objective == "weighted":
        wuv = node_weight(u, cfg) * node_weight(v, cfg)
        w = ht_lookup(st.weab, ca, cb)
        tw = wt_of(st, a, b, a == b)
        st = st._replace(
            phi=st.phi + jnp.where(ok, cost(w - wuv, tw) - cost(w, tw), 0))
        st = pair_weight_add(st, a, b, -wuv, ok)
    else:
        e = ht_lookup(st.eab, ca, cb)
        t = t_of(st.ssize[a], st.ssize[b], a == b)
        st = st._replace(
            phi=st.phi + jnp.where(ok, cost(e - 1, t) - cost(e, t), 0))
    st = pair_count_add(st, a, b, jnp.int32(-1), ok)
    st = _adj_remove(st, u, v, ok)
    st = _adj_remove(st, v, u, ok)
    st = st._replace(num_edges=st.num_edges - jnp.where(ok, 1, 0))

    def fix(st, x, other):
        upd = ok & (st.minh[x] == mixhash(other))
        mh = _minh_recompute(st, x, d_cap)
        return st._replace(
            minh=st.minh.at[x].set(jnp.where(upd, mh, st.minh[x])))

    st = fix(st, u, v)
    st = fix(st, v, u)
    return st


# --------------------------------------------------------------------------- #
# moves
# --------------------------------------------------------------------------- #


def _first_occurrence(x: jax.Array) -> jax.Array:
    """Mask of first occurrences (dedupe) for a small 1-D int array."""
    eq = x[None, :] == x[:, None]
    earlier = jnp.tril(eq, k=-1).any(axis=1)
    return ~earlier


def delta_phi_move(st: EngineState, y: jax.Array, target: jax.Array,
                   is_fresh: jax.Array, cfg: EngineConfig,
                   ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """(dphi, nbrs, nvalid): closed-form phi change of moving y -> target.

    ``is_fresh`` marks an escape to a brand-new singleton (size 0 before the
    move).  Caller guarantees deg(y) <= d_cap and sndeg bounds <= sn_cap.
    """
    d_cap, sn_cap = cfg.d_cap, cfg.sn_cap
    a = st.n2s[y]
    sa = st.ssize[a]
    sb = jnp.where(is_fresh, 0, st.ssize[target])

    nbrs, nvalid = neighbor_slots(st, y, d_cap)
    nsid = jnp.where(nvalid, st.n2s[jnp.clip(nbrs, 0)], -1)

    sl = jnp.arange(sn_cap, dtype=jnp.int32)
    sn_a = jnp.where(sl < st.sndeg[a],
                     ht_lookup_batch(st.snadj, jnp.full((sn_cap,), a, jnp.int32),
                                     sl, default=-1), -1)
    sndeg_b = jnp.where(is_fresh, 0, st.sndeg[target])
    sn_b = jnp.where(sl < sndeg_b,
                     ht_lookup_batch(st.snadj,
                                     jnp.full((sn_cap,), target, jnp.int32),
                                     sl, default=-1), -1)

    xs = jnp.concatenate([nsid, sn_a, sn_b])            # [L]
    first = _first_occurrence(xs)
    is_ab = (xs == a) | (xs == target)
    ok = (xs >= 0) & first & ~is_ab

    # h[X] = |N(y) ∩ X|
    h = (xs[:, None] == nsid[None, :]).sum(axis=1).astype(jnp.int32)
    sx = st.ssize[jnp.clip(xs, 0)]
    xa = jnp.minimum(a, xs)
    xb = jnp.maximum(a, xs)
    e_ax = ht_lookup_batch(st.eab, xa, xb)
    ta, tb = jnp.minimum(target, xs), jnp.maximum(target, xs)
    e_bx = ht_lookup_batch(st.eab, ta, tb)

    d_gen = (cost(e_ax - h, (sa - 1) * sx) - cost(e_ax, sa * sx)
             + cost(e_bx + h, (sb + 1) * sx) - cost(e_bx, sb * sx))
    d = jnp.sum(jnp.where(ok, d_gen, 0))

    # special pairs (A,A), (B,B), (A,B)
    h_a = jnp.sum(nsid == a).astype(jnp.int32)
    h_b = jnp.sum(nsid == target).astype(jnp.int32)
    e_aa = ht_lookup(st.eab, a, a)
    e_bb = jnp.where(is_fresh, 0, ht_lookup(st.eab, target, target))
    pa, pb = canon(a, target)
    e_ab = jnp.where(is_fresh, 0, ht_lookup(st.eab, pa, pb))
    d += cost(e_aa - h_a, tri(sa - 1)) - cost(e_aa, tri(sa))
    d += cost(e_bb + h_b, tri(sb + 1)) - cost(e_bb, tri(sb))
    d += (cost(e_ab - h_b + h_a, (sa - 1) * (sb + 1)) - cost(e_ab, sa * sb))
    return d, nbrs, nvalid


def delta_phi_move_weighted(st: EngineState, y: jax.Array, target: jax.Array,
                            is_fresh: jax.Array, cfg: EngineConfig,
                            ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Weighted-objective :func:`delta_phi_move`: identical structure with
    (E, T, sizes) replaced by (W, TW, weight sums).  Under uniform weights
    every intermediate equals its exact counterpart, so dphi is
    bit-identical (the property test in ``tests/test_policies.py``).
    """
    d_cap, sn_cap = cfg.d_cap, cfg.sn_cap
    a = st.n2s[y]
    wy = node_weight(y, cfg)
    swa, sqa = st.wsum[a], st.wsq[a]
    swb = jnp.where(is_fresh, 0, st.wsum[target])
    sqb = jnp.where(is_fresh, 0, st.wsq[target])

    nbrs, nvalid = neighbor_slots(st, y, d_cap)
    nsid = jnp.where(nvalid, st.n2s[jnp.clip(nbrs, 0)], -1)
    nw = jnp.where(nvalid, node_weight(jnp.clip(nbrs, 0), cfg), 0)

    sl = jnp.arange(sn_cap, dtype=jnp.int32)
    sn_a = jnp.where(sl < st.sndeg[a],
                     ht_lookup_batch(st.snadj, jnp.full((sn_cap,), a, jnp.int32),
                                     sl, default=-1), -1)
    sndeg_b = jnp.where(is_fresh, 0, st.sndeg[target])
    sn_b = jnp.where(sl < sndeg_b,
                     ht_lookup_batch(st.snadj,
                                     jnp.full((sn_cap,), target, jnp.int32),
                                     sl, default=-1), -1)

    xs = jnp.concatenate([nsid, sn_a, sn_b])            # [L]
    first = _first_occurrence(xs)
    is_ab = (xs == a) | (xs == target)
    ok = (xs >= 0) & first & ~is_ab

    # hw[X] = w(y) * sum of w(nbr) over N(y) ∩ X  (weighted h[X])
    hw = wy * (jnp.where(xs[:, None] == nsid[None, :], nw[None, :], 0)
               .sum(axis=1).astype(jnp.int32))
    swx = st.wsum[jnp.clip(xs, 0)]
    xa = jnp.minimum(a, xs)
    xb = jnp.maximum(a, xs)
    w_ax = ht_lookup_batch(st.weab, xa, xb)
    ta, tb = jnp.minimum(target, xs), jnp.maximum(target, xs)
    w_bx = ht_lookup_batch(st.weab, ta, tb)

    d_gen = (cost(w_ax - hw, (swa - wy) * swx) - cost(w_ax, swa * swx)
             + cost(w_bx + hw, (swb + wy) * swx) - cost(w_bx, swb * swx))
    d = jnp.sum(jnp.where(ok, d_gen, 0))

    # special pairs (A,A), (B,B), (A,B)
    hw_a = wy * jnp.sum(jnp.where(nsid == a, nw, 0)).astype(jnp.int32)
    hw_b = wy * jnp.sum(jnp.where(nsid == target, nw, 0)).astype(jnp.int32)
    w_aa = ht_lookup(st.weab, a, a)
    w_bb = jnp.where(is_fresh, 0, ht_lookup(st.weab, target, target))
    pa, pb = canon(a, target)
    w_ab = jnp.where(is_fresh, 0, ht_lookup(st.weab, pa, pb))
    d += (cost(w_aa - hw_a, wtri(swa - wy, sqa - wy * wy))
          - cost(w_aa, wtri(swa, sqa)))
    d += (cost(w_bb + hw_b, wtri(swb + wy, sqb + wy * wy))
          - cost(w_bb, wtri(swb, sqb)))
    d += (cost(w_ab - hw_b + hw_a, (swa - wy) * (swb + wy))
          - cost(w_ab, swa * swb))
    return d, nbrs, nvalid


def apply_move(st: EngineState, y: jax.Array, target: jax.Array,
               dphi: jax.Array, nbrs: jax.Array, nvalid: jax.Array,
               cfg: EngineConfig, ok=True) -> EngineState:
    """Commit the move (target sid must already be allocated by the caller).

    Masked under ``~ok``: the neighbor loop still runs its fixed ``d_cap``
    iterations, but every pair-count/SN/size write is a write-back no-op.
    """
    y = jnp.where(ok, y, 0)
    target = jnp.where(ok, target, 0)
    a = jnp.where(ok, st.n2s[y], 0)
    weighted = cfg.objective == "weighted"
    wy = node_weight(y, cfg)

    def body(i, st):
        w_ok = ok & nvalid[i]
        w = jnp.where(w_ok, nbrs[i], 0)
        sw = st.n2s[w]
        st = pair_count_add(st, a, sw, jnp.int32(-1), w_ok)
        st = pair_count_add(st, target, sw, jnp.int32(1), w_ok)
        if weighted:
            wyv = wy * node_weight(w, cfg)
            st = pair_weight_add(st, a, sw, -wyv, w_ok)
            st = pair_weight_add(st, target, sw, wyv, w_ok)
        return st

    # nvalid is a prefix mask (slot < deg), so a dynamic trip count visits
    # exactly the valid slots — and zero of them on a masked call
    n_upd = jnp.where(ok, jnp.sum(nvalid.astype(jnp.int32)), 0)
    st = jax.lax.fori_loop(0, n_upd, body, st)
    d1 = jnp.where(ok, 1, 0)
    ssize = st.ssize.at[a].add(-d1).at[target].add(d1)
    st = st._replace(
        n2s=st.n2s.at[y].set(jnp.where(ok, target, st.n2s[y])),
        ssize=ssize,
        phi=st.phi + jnp.where(ok, dphi, 0))
    if weighted:
        dw = jnp.where(ok, wy, 0)
        dq = jnp.where(ok, wy * wy, 0)
        st = st._replace(
            wsum=st.wsum.at[a].add(-dw).at[target].add(dw),
            wsq=st.wsq.at[a].add(-dq).at[target].add(dq))

    # a emptied -> push it back on the free stack (masked write otherwise)
    push = ok & (ssize[a] == 0)
    slot = jnp.minimum(st.free_top, st.free.shape[0] - 1)
    return st._replace(
        free=st.free.at[slot].set(jnp.where(push, a, st.free[slot])),
        free_top=st.free_top + jnp.where(push, 1, 0))


def alloc_sid(st: EngineState, ok=True) -> Tuple[EngineState, jax.Array]:
    top = st.free_top - jnp.where(ok, 1, 0)
    sid = st.free[jnp.maximum(st.free_top - 1, 0)]
    return st._replace(free_top=top), sid


# --------------------------------------------------------------------------- #
# audits (host/test use)
# --------------------------------------------------------------------------- #


def recompute_phi(st: EngineState,
                  cfg: EngineConfig | None = None) -> jax.Array:
    """Fold the optimal-encoding cost over all live pair entries.

    Uses the weighted table/quantities when ``cfg`` selects the weighted
    objective; the exact E_AB fold otherwise.
    """
    if cfg is not None and cfg.objective == "weighted":
        live = st.weab.k1 >= 0
        a = jnp.clip(st.weab.k1, 0)
        b = jnp.clip(st.weab.k2, 0)
        tw = wt_of(st, a, b, a == b)
        return jnp.sum(jnp.where(live, cost(st.weab.val, tw), 0))
    live = st.eab.k1 >= 0
    a = jnp.clip(st.eab.k1, 0)
    b = jnp.clip(st.eab.k2, 0)
    t = t_of(st.ssize[a], st.ssize[b], a == b)
    return jnp.sum(jnp.where(live, cost(st.eab.val, t), 0))
