"""Batched trial engine: the jitted MoSSo step (Tier B).

One ``step(state, batch)`` applies B stream changes and then runs, for every
input node, the paper's trial loop (Alg. 1) in fixed shape:

  1. TP(u): ``c`` uniform neighbor samples — O(1) each via the slot-indexed
     adjacency (the TPU-native replacement of GetRandomNeighbor, Thm. 1-3).
  2. TN filter: keep testing node w with probability 1/deg(w).
  3. Corrective escape with probability ``e`` -> fresh singleton.
  4. Otherwise CP(y) = TP(u) ∩ R(y) via min-hash equality; uniform candidate.
  5. Accept iff the closed-form dphi <= 0 (Move if Saved, Stay otherwise).

Capacity guards (deg <= d_cap, |SN| <= sn_cap) skip — never corrupt — trials
that exceed the fixed shapes; skips are counted in ``n_skipped``.
"""
from __future__ import annotations

from functools import partial
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.core.engine.hashtable import ht_lookup_batch
from repro.core.engine.ops import (alloc_sid, apply_move, delete_edge,
                                   delta_phi_move, insert_edge, rnd_below,
                                   rnd_u01, rnd_u32)
from repro.core.engine.state import NO_CLUSTER, EngineConfig, EngineState


def _one_trial(st: EngineState, y: jax.Array, tp: jax.Array,
               tp_minh: jax.Array, seed: jax.Array, cfg: EngineConfig,
               ) -> EngineState:
    """Steps 3-5 of Alg. 1 for one testing node y."""
    a = st.n2s[y]
    esc = rnd_u01(seed, jnp.uint32(3)) <= cfg.escape

    # candidate selection: CP(y) = TP(u) ∩ R(y) (min-hash cluster match)
    my = st.minh[y]
    cp_mask = (tp_minh == my) & (my != NO_CLUSTER)
    n_cp = jnp.sum(cp_mask).astype(jnp.int32)
    pick = rnd_below(seed, jnp.uint32(4), n_cp)
    # index of the pick-th True in cp_mask
    csum = jnp.cumsum(cp_mask.astype(jnp.int32)) - 1
    zidx = jnp.argmax((csum == pick) & cp_mask)
    z = tp[zidx]
    cand_target = st.n2s[z]

    fresh_sid = st.free[jnp.maximum(st.free_top - 1, 0)]
    target = jnp.where(esc, fresh_sid, cand_target)

    cap_ok = ((st.deg[y] <= cfg.d_cap)
              & (st.sndeg[a] <= cfg.sn_cap)
              & (esc | (st.sndeg[cand_target] <= cfg.sn_cap))
              & ((~esc) | (st.free_top > 0)))
    sem_ok = jnp.where(esc, st.ssize[a] > 1, (n_cp > 0) & (cand_target != a))
    ok = cap_ok & sem_ok

    def evaluate(st: EngineState) -> EngineState:
        dphi, nbrs, nvalid = delta_phi_move(st, y, target, esc, cfg)
        accept = dphi <= 0

        def commit(st: EngineState) -> EngineState:
            st = jax.lax.cond(esc, lambda s: alloc_sid(s)[0], lambda s: s, st)
            st = apply_move(st, y, target, dphi, nbrs, nvalid)
            return st._replace(n_accept=st.n_accept + 1)

        st = jax.lax.cond(accept, commit, lambda s: s, st)
        return st._replace(n_trials=st.n_trials + 1)

    def skipped(st: EngineState) -> EngineState:
        return st._replace(
            n_trials=st.n_trials + 1,
            n_skipped=st.n_skipped + jnp.where(~cap_ok, 1, 0).astype(jnp.int32))

    return jax.lax.cond(ok, evaluate, skipped, st)


def _trial_group(st: EngineState, u: jax.Array, seed: jax.Array,
                 cfg: EngineConfig) -> EngineState:
    """Steps 1-5 of Alg. 1 for one input node u."""

    def run(st: EngineState) -> EngineState:
        du = st.deg[u]
        ks = jnp.arange(cfg.c, dtype=jnp.uint32)
        ridx = jax.vmap(lambda k: rnd_below(seed, k * 8 + 1, du))(ks)
        tp = ht_lookup_batch(st.adj, jnp.full((cfg.c,), u, jnp.int32), ridx,
                             default=0)
        tp_minh = st.minh[tp]

        def body(k, st):
            y = tp[k]
            tseed = rnd_u32(seed, jnp.uint32(100) + k.astype(jnp.uint32))
            # TN filter: testing prob 1/deg(w)  (Careful Selection (1))
            keep = rnd_u01(tseed, jnp.uint32(2)) * st.deg[y].astype(jnp.float32) <= 1.0
            return jax.lax.cond(
                keep, lambda s: _one_trial(s, y, tp, tp_minh, tseed, cfg),
                lambda s: s, st)

        return jax.lax.fori_loop(0, cfg.c, body, st)

    valid = (u >= 0) & (st.n2s[jnp.clip(u, 0)] >= 0) & (st.deg[jnp.clip(u, 0)] > 0)
    return jax.lax.cond(valid, run, lambda s: s, st)


def _apply_change(st: EngineState, u: jax.Array, v: jax.Array,
                  ins: jax.Array, cfg: EngineConfig) -> EngineState:
    valid = u >= 0
    st = jax.lax.cond(valid & ins,
                      lambda s: insert_edge(s, u, v, cfg.d_cap),
                      lambda s: s, st)
    st = jax.lax.cond(valid & (~ins),
                      lambda s: delete_edge(s, u, v, cfg.d_cap),
                      lambda s: s, st)
    return st


def step_fn(st: EngineState, u: jax.Array, v: jax.Array, ins: jax.Array,
            cfg: EngineConfig) -> EngineState:
    """One jitted engine step over a padded batch of changes.

    Batch semantics (DESIGN.md deviation #3): all changes apply first, then
    trial groups run for every endpoint in stream order.
    """

    def ap(st, ch):
        return _apply_change(st, ch[0], ch[1], ch[2] != 0, cfg), None

    changes = jnp.stack([u, v, ins.astype(jnp.int32)], axis=1)
    st, _ = jax.lax.scan(ap, st, changes)

    nodes = jnp.stack([u, v], axis=1).reshape(-1)  # u0,v0,u1,v1,...

    def tg(st, xs):
        node, idx = xs
        seed = rnd_u32(st.step_no, idx.astype(jnp.uint32) * jnp.uint32(2654435761))
        return _trial_group(st, node, seed, cfg), None

    st, _ = jax.lax.scan(tg, st, (nodes, jnp.arange(nodes.shape[0], dtype=jnp.int32)))
    return st._replace(step_no=st.step_no + jnp.uint32(1))


def make_step(cfg: EngineConfig):
    """Compile the engine step for a fixed config."""
    return jax.jit(partial(step_fn, cfg=cfg))
