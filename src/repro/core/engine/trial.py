"""Batched trial engine: the jitted MoSSo step (Tier B).

One ``step(state, batch)`` applies B stream changes and then runs, for every
input node, the paper's trial loop (Alg. 1) in fixed shape:

  1. TP(u): ``c`` uniform neighbor samples — O(1) each via the slot-indexed
     adjacency (the TPU-native replacement of GetRandomNeighbor, Thm. 1-3).
  2. TN filter: keep testing node w with probability 1/deg(w).
  3. Corrective escape with probability ``e`` -> fresh singleton.
  4. Otherwise a candidate destination from the PROPOSAL policy (default:
     CP(y) = TP(u) ∩ R(y) via min-hash equality; uniform candidate).
  5. Score with the OBJECTIVE policy (default: exact closed-form dphi) and
     accept per the COMMIT policy (default: dphi <= 0, Move if Saved).

Steps 4-5 dispatch through ``repro.core.engine.policies`` on the static
``EngineConfig`` policy triple — resolved at trace time, so every
registered combination compiles cond-free and the default triple is
bit-identical to the historical hard-coded engine.

Capacity guards (deg <= d_cap, |SN| <= sn_cap) skip — never corrupt — trials
that exceed the fixed shapes; skips are counted in ``n_skipped``.

**Cond-free invariant.**  The step contains ZERO ``lax.cond``: Alg. 1 is
lowered as *predicated data flow*.  Every trial computes its arms as
masked data flow (candidate selection, closed-form dphi, the masked move)
and commits through the ``ok`` predicates of the ops layer
(:mod:`repro.core.engine.ops`), so a rejected/skipped/filtered trial is a
bit-exact structural no-op.  The PRNG is counter-based and stateless, so
masked lanes drawing (and discarding) randomness cannot shift any other
lane's stream — the predicated step is bit-identical to the historical
``lax.cond`` lowering on identical inputs.

**Two lowerings, one semantics.**  The step compiles in one of two
modes, selected by the static ``dense`` flag of :func:`step_fn` /
:func:`make_step` — both bit-identical, because every write is masked
either way:

* ``dense=True`` — the change-application ops (:func:`_apply_change`)
  execute unconditionally and commit under their predicates.  This is
  the lowering the ``jax.vmap``-over-replicas layout uses
  (``repro/dist/router.py``): a batched 0/1-trip while region pays a
  per-lane select over its whole carry on every fire, which for a
  state-carrying region that fires once per change costs more than the
  masked ops themselves.
* ``dense=False`` — those regions short-circuit through :func:`pwhen`
  (never a ``lax.cond``), the fast lowering for serial execution where a
  dead region costs one trip-count check.

The trial loop itself needs no mode split: it is phased (see
:func:`_one_trial`) so its frequent predicated regions are *pure* and
carry only scalars — cheap under both lowerings — and engine state is
carried only by the commit tail, which fires at the move-acceptance
rate.  Only the per-node ``lax.scan`` — stream-order semantics — stays
sequential in both modes.
"""
from __future__ import annotations

from functools import lru_cache
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.core.engine import policies
from repro.core.engine.hashtable import (ht_lookup_batch,
                                         resolve_trial_backend,
                                         trial_backend_scope)
from repro.core.engine.ops import (alloc_sid, apply_move, delete_edge,
                                   insert_edge, rnd_below, rnd_u01, rnd_u32)
from repro.core.engine.state import EngineConfig, EngineState


def pwhen(pred: jax.Array, fn, carry):
    """Uniformly-predicated region: apply ``fn`` to ``carry`` iff ``pred``.

    Lowers to a 0/1-trip ``lax.while_loop``, NOT a ``lax.cond``: a dead
    predicate costs one trip check, and under ``jax.vmap`` the body runs
    (batched, at most once) iff any lane is live — SIMT predication.  ``fn``
    must itself commit through masked writes, because with mixed live/dead
    lanes it executes for all of them; the loop's per-lane carry select is
    the second, redundant layer of protection.  ``carry`` may be any
    pytree (the router predicates its intern path with it too).
    """
    done = jax.lax.while_loop(
        lambda c: c[0],
        lambda c: (jnp.zeros_like(c[0]), fn(c[1])),
        (pred, carry))
    return done[1]


def _pregion(pred: jax.Array, fn, carry, dense: bool):
    """One predicated region, lowered per the step's ``dense`` mode.

    ``dense=True`` executes ``fn`` unconditionally — correct because every
    write inside commits under its own mask; this is what the vmapped
    replica layout compiles, where a batched :func:`pwhen` would pay
    full-carry selects per fire.  ``dense=False`` short-circuits through
    :func:`pwhen`."""
    if dense:
        return fn(carry)
    return pwhen(pred, fn, carry)


def _one_trial(st: EngineState, y: jax.Array, tp: jax.Array,
               tp_minh: jax.Array, seed: jax.Array, cfg: EngineConfig,
               pred: jax.Array, dense: bool) -> EngineState:
    """Steps 3-5 of Alg. 1 for one testing node y, committed under ``pred``.

    ``pred`` folds the group-validity and TN-filter gates.  The trial is
    phased so every :func:`pwhen` carries as little as possible — that is
    what makes the SAME lowering optimal serial AND vmapped (a batched
    while loop selects its *carry* per lane on every fire; closed-over
    loop inputs like ``st`` in the pure phases are free):

    1. ``plan`` (under ``pred``) — candidate selection: pure reads, the
       carry is a handful of scalars.
    2. ``eval_phi`` (under ``ok``) — the closed-form dphi: pure reads,
       the carry is the ``d_cap`` neighbor slots.
    3. the commit tail (under ``commit``) — the only phase that carries
       engine state, firing at the (rare) move-acceptance rate.
    4. trial counters — masked scalar adds, always.

    The phases are SIBLINGS, never nested: a ``pwhen`` inside a batched
    ``pwhen`` body promotes the inner region's closed-over state into the
    outer loop's carry, reintroducing exactly the full-state copies the
    small carries avoid.

    **Policy dispatch.**  The candidate scheme, the dphi objective, and
    the accept rule are resolved HERE, at trace time, from the static
    config fields (``repro.core.engine.policies``) — plain Python lookups,
    so a compiled step bakes in exactly one policy triple and the
    cond-free invariant holds for every registered combination.  The
    default triple reproduces the pre-policy-layer expressions (and PRNG
    counters) exactly, keeping it bit-identical to the historical engine.
    """
    d_cap = cfg.d_cap
    propose = policies.PROPOSALS[cfg.proposal]
    objective = policies.OBJECTIVES[cfg.objective]
    accept = policies.COMMIT_RULES[cfg.commit]

    def plan(carry):
        a = st.n2s[y]
        esc = rnd_u01(seed, jnp.uint32(3)) <= cfg.escape

        # candidate selection (proposal policy); counters 4.. are reserved
        # for the proposal's own draws
        cand_target, cand_ok = propose(st, y, tp, tp_minh, seed, cfg)

        fresh_sid = st.free[jnp.maximum(st.free_top - 1, 0)]
        target = jnp.where(esc, fresh_sid, cand_target)

        cap_ok = ((st.deg[y] <= cfg.d_cap)
                  & (st.sndeg[a] <= cfg.sn_cap)
                  & (esc | (st.sndeg[cand_target] <= cfg.sn_cap))
                  & ((~esc) | (st.free_top > 0)))
        sem_ok = jnp.where(esc, st.ssize[a] > 1, cand_ok)
        ok = pred & cap_ok & sem_ok
        return esc, a, target, ok, cap_ok

    f = jnp.zeros((), bool)
    z32 = jnp.int32(0)
    esc, a, target, ok, cap_ok = _pregion(pred, plan, (f, z32, z32, f, f),
                                          dense)

    def eval_phi(c):
        # masked data flow: dphi of the candidate move (a -> a when the
        # trial is masked, so every gather stays in bounds)
        tgt_s = jnp.clip(jnp.where(ok, target, a), 0)
        return objective(st, y, tgt_s, esc, cfg)

    c2 = (z32, jnp.full((d_cap,), -1, jnp.int32), jnp.zeros((d_cap,), bool))
    dphi, nbrs, nvalid = pwhen(ok, eval_phi, c2)
    commit = ok & accept(dphi, cfg)

    def commit_tail(st: EngineState) -> EngineState:
        st = alloc_sid(st, ok=commit & esc)[0]
        st = apply_move(st, y, target, dphi, nbrs, nvalid, cfg, ok=commit)
        return st._replace(
            n_accept=st.n_accept + jnp.where(commit, 1, 0).astype(jnp.int32))

    st = pwhen(commit, commit_tail, st)
    return st._replace(
        n_trials=st.n_trials + jnp.where(pred, 1, 0).astype(jnp.int32),
        n_skipped=st.n_skipped
        + jnp.where(pred & ~cap_ok, 1, 0).astype(jnp.int32))


def _trial_group(st: EngineState, u: jax.Array, seed: jax.Array,
                 cfg: EngineConfig, dense: bool) -> EngineState:
    """Steps 1-5 of Alg. 1 for one input node u (predicated, cond-free).

    The TP-sampling preamble is pure and cheap, so it runs unmasked for
    every lane (including padding, with a clipped index); ``valid`` rides
    into each trial's predicate instead.
    """
    u_s = jnp.clip(u, 0)
    valid = (u >= 0) & (st.n2s[u_s] >= 0) & (st.deg[u_s] > 0)

    du = st.deg[u_s]
    ks = jnp.arange(cfg.c, dtype=jnp.uint32)
    ridx = jax.vmap(lambda k: rnd_below(seed, k * 8 + 1, du))(ks)
    tp = ht_lookup_batch(st.adj, jnp.full((cfg.c,), u_s, jnp.int32),
                         ridx, default=0)
    tp_minh = st.minh[tp]

    def body(k, st):
        y = tp[k]
        tseed = rnd_u32(seed, jnp.uint32(100) + k.astype(jnp.uint32))
        # TN filter: testing prob 1/deg(w)  (Careful Selection (1))
        keep = (rnd_u01(tseed, jnp.uint32(2))
                * st.deg[y].astype(jnp.float32) <= 1.0)
        return _one_trial(st, y, tp, tp_minh, tseed, cfg,
                          pred=valid & keep, dense=dense)

    return jax.lax.fori_loop(0, cfg.c, body, st)


def _apply_change(st: EngineState, u: jax.Array, v: jax.Array,
                  ins: jax.Array, cfg: EngineConfig, dense: bool,
                  ) -> EngineState:
    valid = u >= 0
    do_ins = valid & ins
    do_del = valid & ~ins
    st = _pregion(do_ins,
                  lambda s: insert_edge(s, u, v, cfg, ok=do_ins),
                  st, dense)
    st = _pregion(do_del,
                  lambda s: delete_edge(s, u, v, cfg, ok=do_del),
                  st, dense)
    return st


def step_fn(st: EngineState, u: jax.Array, v: jax.Array, ins: jax.Array,
            cfg: EngineConfig, dense: bool = False) -> EngineState:
    """One jitted engine step over a padded batch of changes.

    Batch semantics (DESIGN.md deviation #3): all changes apply first, then
    trial groups run for every endpoint in stream order.
    """

    def ap(st, ch):
        return _apply_change(st, ch[0], ch[1], ch[2] != 0, cfg,
                             dense), None

    changes = jnp.stack([u, v, ins.astype(jnp.int32)], axis=1)
    st, _ = jax.lax.scan(ap, st, changes)

    nodes = jnp.stack([u, v], axis=1).reshape(-1)  # u0,v0,u1,v1,...

    def tg(st, xs):
        node, idx = xs
        seed = rnd_u32(st.step_no, idx.astype(jnp.uint32) * jnp.uint32(2654435761))
        return _trial_group(st, node, seed, cfg, dense), None

    st, _ = jax.lax.scan(tg, st, (nodes, jnp.arange(nodes.shape[0], dtype=jnp.int32)))
    return st._replace(step_no=st.step_no + jnp.uint32(1))


@lru_cache(maxsize=None)
def _make_step(cfg: EngineConfig, dense: bool, trial_backend: str):
    # the backend scope is entered INSIDE the jitted body: jit traces
    # lazily at first call, and the scope must be active while the
    # batched-probe call sites trace so the compiled program bakes in
    # exactly the requested backend
    def stepped(st, u, v, ins):
        with trial_backend_scope(trial_backend):
            return step_fn(st, u, v, ins, cfg, dense)

    return jax.jit(stepped)


def make_step(cfg: EngineConfig, dense: bool = False,
              trial_backend: str | None = None):
    """Compile the engine step for a fixed config (and lowering mode).

    Memoized on the (hashable) config — plus the lowering mode and the
    resolved batched-probe backend (``trial_backend``: explicit arg >
    active scope > ``REPRO_TRIAL_BACKEND`` env > ``"xla"``) — so
    same-config summarizers, e.g. the two sides of a differential test,
    share one compiled program per backend.
    """
    return _make_step(cfg, dense, resolve_trial_backend(trial_backend))
