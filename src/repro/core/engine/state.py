"""Fixed-shape engine state for the batched TPU-native MoSSo (Tier B).

Everything lives in preallocated arrays/hash tables so a summarization step
is a pure jitted function ``(state, change_batch, seed) -> state``.

Capacity model (host-validated): ``n_cap`` nodes, ``m_cap`` live undirected
edges, movable-node degree bound ``d_cap``, supernode-adjacency bound
``sn_cap``.  Hash tables are sized at ~4x their worst-case live entries so
linear probing stays O(1) (see `hashtable.py`).
"""
from __future__ import annotations

import dataclasses
import os
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core.engine.hashtable import HashTable, ht_new

NO_CLUSTER = jnp.int32(0x7FFFFFFF)

# Canonical policy names.  The implementations live in
# ``repro.core.engine.policies`` (which imports this module, so only the
# name tuples can live here); a test pins the registry keys to these
# tuples so they cannot drift.
PROPOSALS = ("minhash", "magsdm")
OBJECTIVES = ("exact", "weighted")
COMMIT_RULES = ("saving", "threshold")


@dataclasses.dataclass(frozen=True)
class EngineConfig:
    """Capacity and search parameters of one engine instance.

    **Id space.** The engine is oblivious to caller labels: it consumes
    dense node ids in ``[0, n_cap)`` (every state array below is indexed by
    them).  Front-ends own the translation — ``BatchedSummarizer`` interns
    labels on the host; ``ShardedSummarizer`` interns per shard on device
    (``repro/dist/router.py``), so under sharding ``n_cap`` is a PER-SHARD
    budget and, edge partitioning being a vertex cut, must cover the node
    replication factor, not just ``|V| / n_shards``.

    **Capacity semantics.** ``n_cap`` is hard: interning past it fails
    fast (host assert in ``BatchedSummarizer``, or a device drop counter
    that raises at the next sync under sharding).  ``m_cap`` is a sizing
    contract, not a checked bound: it fixes the hash-table capacities
    (``table_caps``) at ~4x their worst-case live entries, so streaming
    more than ``m_cap`` live edges degrades probe chains instead of
    erroring — monitor ``table_pressure()``/``maybe_compact()`` on long
    streams.  ``d_cap``/``sn_cap`` are soft trial bounds: trials that
    would exceed them are skipped — never corrupted — and counted in
    ``n_skipped`` (DESIGN deviation #1).

    **Policy triple.** ``proposal`` / ``objective`` / ``commit`` select the
    Alg.-1 policies (candidate generation, move scoring, accept rule) as
    STATIC fields: policy dispatch happens at trace time (plain Python
    dict lookup in ``trial.py`` / ``policies.py``, never a ``lax.cond``),
    and because the config is frozen/hashable, every compile cache —
    ``make_step``'s ``lru_cache`` and the router's ``_STEP_CACHE`` — keys
    on the resolved triple automatically.  Defaults come from
    ``REPRO_PROPOSAL`` / ``REPRO_OBJECTIVE`` (the ``REPRO_TRIAL_BACKEND``
    pattern) so the CI matrix can flip them for a whole suite.
    ``weight_levels`` parameterizes the ``weighted`` objective's node
    weights ``w(u) = 1 + (hash(u) % weight_levels)``; ``0``/``1`` mean
    uniform weights, under which the weighted objective is bit-identical
    to ``exact``.  Keep it small: per-supernode ``SW**2`` must stay below
    2**31 (int32 TW products).
    """

    n_cap: int = 1 << 14          # max distinct nodes (per engine/shard)
    m_cap: int = 1 << 17          # max live undirected edges
    d_cap: int = 64               # movable-node degree bound (deviation #1)
    sn_cap: int = 32              # supernode-adjacency bound for moves
    c: int = 20                   # samples per input node (paper's c)
    escape: float = 0.3           # corrective-escape probability (paper's e)
    batch: int = 32               # changes per jitted step
    seed: int = 0
    # policy triple (static: part of every compile-cache key)
    proposal: str = dataclasses.field(
        default_factory=lambda: os.environ.get("REPRO_PROPOSAL", "minhash"))
    objective: str = dataclasses.field(
        default_factory=lambda: os.environ.get("REPRO_OBJECTIVE", "exact"))
    commit: str = "saving"
    commit_margin: int = 0        # accept iff dphi <= margin ("threshold")
    weight_levels: int = 0        # 0/1 = uniform node weights ("weighted")

    def __post_init__(self):
        if self.proposal not in PROPOSALS:
            raise ValueError(f"unknown proposal {self.proposal!r}; "
                             f"expected one of {PROPOSALS}")
        if self.objective not in OBJECTIVES:
            raise ValueError(f"unknown objective {self.objective!r}; "
                             f"expected one of {OBJECTIVES}")
        if self.commit not in COMMIT_RULES:
            raise ValueError(f"unknown commit rule {self.commit!r}; "
                             f"expected one of {COMMIT_RULES}")

    def manifest(self) -> dict:
        """JSON-able identity of this config for checkpoint manifests.

        Every field participates: the table capacities, the policy triple
        and the PRNG seed all shape the engine state arrays and the trial
        schedule, so a checkpoint taken under one config is only bitwise
        replayable under an equal config (``repro.checkpoint.summary``
        refuses a mismatched restore instead of silently corrupting).
        """
        return dataclasses.asdict(self)

    def table_caps(self) -> dict:
        def pow2(x: int) -> int:
            c = 1
            while c < x:
                c <<= 1
            return c
        return dict(
            adj=pow2(4 * self.m_cap),      # (u, slot) -> v, two directions
            epos=pow2(4 * self.m_cap),     # (u, v) -> slot, two directions
            eab=pow2(2 * self.m_cap),      # canonical pair -> |E_AB|
            snadj=pow2(2 * self.m_cap),    # (sid, slot) -> sid
            snpos=pow2(2 * self.m_cap),    # (sid, sid) -> slot
            # canonical pair -> W_AB, live iff the eab entry is (positive
            # weights), kept at the same capacity so probe chains match;
            # a 8-slot dummy when the objective doesn't maintain weights
            weab=(pow2(2 * self.m_cap)
                  if self.objective == "weighted" else 8),
        )


class EngineState(NamedTuple):
    # per node
    n2s: jax.Array      # int32[n_cap], -1 = unseen node
    deg: jax.Array      # int32[n_cap]
    minh: jax.Array     # int32[n_cap], min-hash cluster id (NO_CLUSTER if none)
    # per supernode (sid space == node space)
    ssize: jax.Array    # int32[n_cap]
    sndeg: jax.Array    # int32[n_cap], |SN(sid)| (supernodes with E>0)
    free: jax.Array     # int32[n_cap], free sid stack
    free_top: jax.Array  # int32 scalar, #free sids
    # weighted-objective view (dummy 1/8-sized leaves under "exact" so the
    # pytree structure is config-static and the default jaxpr untouched)
    wsum: jax.Array     # int32[n_cap] SW(sid) = sum of member weights
    wsq: jax.Array      # int32[n_cap] SQ(sid) = sum of squared weights
    # tables
    adj: HashTable
    epos: HashTable
    eab: HashTable
    snadj: HashTable
    snpos: HashTable
    weab: HashTable     # canonical pair -> W_AB (weighted objective only)
    # scalars
    phi: jax.Array        # int32
    num_edges: jax.Array  # int32
    step_no: jax.Array    # uint32, PRNG stream position
    # counters for stats
    n_trials: jax.Array
    n_accept: jax.Array
    n_skipped: jax.Array  # trials skipped by capacity guards (deviation audit)


def new_state(cfg: EngineConfig) -> EngineState:
    caps = cfg.table_caps()
    n = cfg.n_cap
    nw = n if cfg.objective == "weighted" else 1
    return EngineState(
        n2s=jnp.full((n,), -1, jnp.int32),
        deg=jnp.zeros((n,), jnp.int32),
        minh=jnp.full((n,), NO_CLUSTER, jnp.int32),
        ssize=jnp.zeros((n,), jnp.int32),
        sndeg=jnp.zeros((n,), jnp.int32),
        free=jnp.arange(n - 1, -1, -1, dtype=jnp.int32),
        free_top=jnp.int32(n),
        wsum=jnp.zeros((nw,), jnp.int32),
        wsq=jnp.zeros((nw,), jnp.int32),
        adj=ht_new(caps["adj"]),
        epos=ht_new(caps["epos"]),
        eab=ht_new(caps["eab"]),
        snadj=ht_new(caps["snadj"]),
        snpos=ht_new(caps["snpos"]),
        weab=ht_new(caps["weab"]),
        phi=jnp.int32(0),
        num_edges=jnp.int32(0),
        step_no=jnp.uint32(cfg.seed),
        n_trials=jnp.int32(0),
        n_accept=jnp.int32(0),
        n_skipped=jnp.int32(0),
    )
