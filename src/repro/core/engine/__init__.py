from repro.core.engine.api import BatchedSummarizer
from repro.core.engine.state import EngineConfig, EngineState, new_state
from repro.core.engine.trial import make_step, step_fn

__all__ = ["BatchedSummarizer", "EngineConfig", "EngineState", "new_state",
           "make_step", "step_fn"]
