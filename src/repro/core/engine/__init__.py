from repro.core.engine.api import BatchedSummarizer, ShardedSummarizer
from repro.core.engine.state import EngineConfig, EngineState, new_state
from repro.core.engine.trial import make_step, step_fn

__all__ = ["BatchedSummarizer", "ShardedSummarizer", "EngineConfig",
           "EngineState", "new_state", "make_step", "step_fn"]
