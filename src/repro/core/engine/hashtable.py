"""Open-addressing hash tables in fixed-shape JAX arrays (Tier B substrate).

The paper assumes "the neighborhood in C+, C- and P of each node is stored in
a hash table" (Thm. 3).  On TPU we realize that assumption with preallocated
HBM-resident open-addressing tables: `int32` key pairs, linear probing,
tombstone deletion.  All operations are pure functions `table -> table` and
compile into bounded `lax.while_loop` probes (expected O(1) probes at the
load factors we configure).

Keys are pairs ``(k1, k2)`` of non-negative int32 so that node-pair and
(node, slot) keys never need 64-bit arithmetic.  ``k1 == EMPTY`` marks a free
slot and ``k1 == TOMB`` a deleted one.

**Predicated writes.**  Every mutating op takes an ``ok`` predicate; a
masked call (``ok=False``) probes as usual but writes the slot's existing
contents back, so it is a structural no-op of constant cost — the
predication contract the branch-free trial engine (``trial.py``) builds on.
Masked calls may receive garbage keys (padding, untaken arms): probe loops
always terminate (a chain ends at EMPTY or wraps after ``cap`` steps) and
nothing is committed.
"""
from __future__ import annotations

import os
from contextlib import contextmanager
from typing import List, NamedTuple, Tuple

import jax
import jax.numpy as jnp

EMPTY = jnp.int32(-1)
TOMB = jnp.int32(-2)

# ---------------------------------------------------------------------- #
# batched-probe backend switch
# ---------------------------------------------------------------------- #
#
# Batched probes — the trial engine's lookups and the router's intern
# pre-lookup — lower in one of two ways:
#
# * ``"xla"`` (default) — ``jax.vmap`` over the scalar probe loops below:
#   one batched ``lax.while_loop`` per call site.  The differential
#   reference, and the only compiled path on CPU.
# * ``"pallas"`` — one fused kernel launch per batch
#   (``repro.kernels.ht_probe``), bit-identical by contract; on the CPU
#   backend it runs in Pallas interpret mode (inlined into the XLA
#   program), so CI can exercise the kernel path end to end.
#
# The backend is resolved at TRACE time: callers that compile a step enter
# :func:`trial_backend_scope` inside the to-be-jitted function body (see
# ``trial.make_step`` / ``dist.router``), so the scope is active while the
# probe call sites trace and each compiled program bakes in exactly one
# backend.  ``REPRO_TRIAL_BACKEND`` sets the process-wide default.
TRIAL_BACKENDS = ("xla", "pallas")
_BACKEND_STACK: List[str] = []


def resolve_trial_backend(backend: str | None = None) -> str:
    """The effective probe backend: explicit arg > active scope > env."""
    if backend is None:
        backend = (_BACKEND_STACK[-1] if _BACKEND_STACK
                   else os.environ.get("REPRO_TRIAL_BACKEND", "xla"))
    if backend not in TRIAL_BACKENDS:
        raise ValueError(
            f"trial backend must be one of {TRIAL_BACKENDS}: {backend!r}")
    return backend


@contextmanager
def trial_backend_scope(backend: str | None):
    """Pin the batched-probe backend for call sites traced in this scope."""
    _BACKEND_STACK.append(resolve_trial_backend(backend))
    try:
        yield _BACKEND_STACK[-1]
    finally:
        _BACKEND_STACK.pop()


class HashTable(NamedTuple):
    k1: jax.Array  # int32[cap]
    k2: jax.Array  # int32[cap]
    val: jax.Array  # int32[cap]

    @property
    def capacity(self) -> int:
        return self.k1.shape[0]


def ht_new(capacity: int) -> HashTable:
    assert capacity & (capacity - 1) == 0, "capacity must be a power of two"
    return HashTable(
        k1=jnp.full((capacity,), EMPTY, jnp.int32),
        k2=jnp.full((capacity,), EMPTY, jnp.int32),
        val=jnp.zeros((capacity,), jnp.int32),
    )


def _hash(k1: jax.Array, k2: jax.Array, cap: int) -> jax.Array:
    """Two-word integer mix (fmix32-style) onto [0, cap)."""
    h = k1.astype(jnp.uint32) * jnp.uint32(0x85EBCA6B)
    h = h ^ (h >> 13)
    h = h + k2.astype(jnp.uint32) * jnp.uint32(0xC2B2AE35)
    h = h ^ (h >> 16)
    h = h * jnp.uint32(0x27D4EB2F)
    h = h ^ (h >> 15)
    return (h & jnp.uint32(cap - 1)).astype(jnp.int32)


def _probe_start(k1: jax.Array, k2: jax.Array, cap: int,
                 prehashed: bool) -> jax.Array:
    """First probe slot for a key.

    ``prehashed=True`` skips the fmix re-mix and folds the words directly
    onto the table — for tables whose keys are already full-entropy hashes
    (the router's label-intern tables, keyed by 62-bit splitmix64/blake2b
    words).  A table must be accessed with one consistent setting: the
    probe sequence IS the on-device layout.
    """
    if prehashed:
        h = (k1.astype(jnp.uint32) ^ k2.astype(jnp.uint32))
        return (h & jnp.uint32(cap - 1)).astype(jnp.int32)
    return _hash(k1, k2, cap)


def ht_find(ht: HashTable, k1, k2,
            prehashed: bool = False) -> Tuple[jax.Array, jax.Array]:
    """Return (slot, found). Probes until the key or an EMPTY slot is hit."""
    cap = ht.capacity
    k1 = jnp.asarray(k1, jnp.int32)
    k2 = jnp.asarray(k2, jnp.int32)
    start = _probe_start(k1, k2, cap, prehashed)

    def cond(carry):
        i, _ = carry
        slot = (start + i) & (cap - 1)
        hit = (ht.k1[slot] == k1) & (ht.k2[slot] == k2)
        return (~hit) & (ht.k1[slot] != EMPTY) & (i < cap)

    def body(carry):
        i, _ = carry
        return (i + 1, jnp.int32(0))

    i, _ = jax.lax.while_loop(cond, body, (jnp.int32(0), jnp.int32(0)))
    slot = (start + i) & (cap - 1)
    found = (ht.k1[slot] == k1) & (ht.k2[slot] == k2)
    return slot, found


def ht_lookup(ht: HashTable, k1, k2, default=0) -> jax.Array:
    slot, found = ht_find(ht, k1, k2)
    return jnp.where(found, ht.val[slot], jnp.int32(default))


def _probe_batch(ht: HashTable, k1: jax.Array, k2: jax.Array,
                 prehashed: bool, backend: str | None,
                 ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Backend dispatch for a batch of find-probes: (slot, found, val).

    ``val`` is the value at the key's chain-end slot — garbage when
    ``~found``; callers select against their own default.  Both backends
    are leaf-bitwise identical (tests/test_kernels.py sweeps this).
    """
    k1 = jnp.asarray(k1, jnp.int32)
    k2 = jnp.asarray(k2, jnp.int32)
    if resolve_trial_backend(backend) == "pallas":
        # lazy import: the kernels layer imports this module for the
        # probe-sequence constants, so the dependency cannot be top-level
        from repro.kernels import ops as _kops
        return _kops.ht_probe(ht.k1, ht.k2, ht.val, k1, k2,
                              prehashed=prehashed, mode="find")
    slot, found = jax.vmap(
        lambda a, b: ht_find(ht, a, b, prehashed=prehashed))(k1, k2)
    return slot, found, ht.val[slot]


def ht_find_batch(ht: HashTable, k1: jax.Array, k2: jax.Array,
                  prehashed: bool = False, backend: str | None = None,
                  ) -> Tuple[jax.Array, jax.Array]:
    """Batched :func:`ht_find`: (slot, found) per query, one fused probe
    pass under the active trial backend."""
    slot, found, _ = _probe_batch(ht, k1, k2, prehashed, backend)
    return slot, found


def ht_lookup_batch(ht: HashTable, k1: jax.Array, k2: jax.Array,
                    default=0, backend: str | None = None) -> jax.Array:
    """Vectorized read-only lookups under the active trial backend."""
    _, found, val = _probe_batch(ht, k1, k2, False, backend)
    return jnp.where(found, val, jnp.int32(default))


def _find_insert_slot(ht: HashTable, k1, k2,
                      prehashed: bool = False) -> Tuple[jax.Array, jax.Array]:
    """Slot for an upsert: the key's slot if present, else first free slot."""
    cap = ht.capacity
    start = _probe_start(k1, k2, cap, prehashed)

    # pass 1: find the key or the end of its probe chain (EMPTY).
    def cond1(i):
        slot = (start + i) & (cap - 1)
        hit = (ht.k1[slot] == k1) & (ht.k2[slot] == k2)
        return (~hit) & (ht.k1[slot] != EMPTY) & (i < cap)

    i1 = jax.lax.while_loop(cond1, lambda i: i + 1, jnp.int32(0))
    slot1 = (start + i1) & (cap - 1)
    found = (ht.k1[slot1] == k1) & (ht.k2[slot1] == k2)

    # pass 2 (only matters when not found): first EMPTY or TOMB slot.
    def cond2(i):
        slot = (start + i) & (cap - 1)
        free = (ht.k1[slot] == EMPTY) | (ht.k1[slot] == TOMB)
        return (~free) & (i < cap)

    i2 = jax.lax.while_loop(cond2, lambda i: i + 1, jnp.int32(0))
    slot2 = (start + i2) & (cap - 1)
    return jnp.where(found, slot1, slot2), found


def ht_set(ht: HashTable, k1, k2, v, prehashed: bool = False,
           ok=True) -> HashTable:
    """Upsert key -> v (masked write-back of the slot when ``~ok``)."""
    k1 = jnp.asarray(k1, jnp.int32)
    k2 = jnp.asarray(k2, jnp.int32)
    slot, _ = _find_insert_slot(ht, k1, k2, prehashed)
    return HashTable(
        k1=ht.k1.at[slot].set(jnp.where(ok, k1, ht.k1[slot])),
        k2=ht.k2.at[slot].set(jnp.where(ok, k2, ht.k2[slot])),
        val=ht.val.at[slot].set(
            jnp.where(ok, jnp.asarray(v, jnp.int32), ht.val[slot])),
    )


def ht_add(ht: HashTable, k1, k2, delta, remove_if_zero: bool = False,
           ok=True) -> Tuple[HashTable, jax.Array]:
    """val[key] += delta (inserting at 0 if absent); returns (table, new val).

    With ``remove_if_zero`` the entry is tombstoned when it reaches 0 —
    used by the E_AB count table so that `SN` adjacency mirrors E>0 pairs.
    ``new`` is the would-be value either way; the table is only mutated
    under ``ok``.
    """
    k1 = jnp.asarray(k1, jnp.int32)
    k2 = jnp.asarray(k2, jnp.int32)
    slot, found = _find_insert_slot(ht, k1, k2)
    old = jnp.where(found, ht.val[slot], jnp.int32(0))
    new = old + jnp.asarray(delta, jnp.int32)
    dead = remove_if_zero & (new == 0)
    return HashTable(
        k1=ht.k1.at[slot].set(
            jnp.where(ok, jnp.where(dead, TOMB, k1), ht.k1[slot])),
        k2=ht.k2.at[slot].set(
            jnp.where(ok, jnp.where(dead, TOMB, k2), ht.k2[slot])),
        val=ht.val.at[slot].set(
            jnp.where(ok, jnp.where(dead, 0, new), ht.val[slot])),
    ), new


def ht_delete(ht: HashTable, k1, k2, ok=True) -> HashTable:
    """Tombstone the key if present (no-op otherwise or when ``~ok``)."""
    k1 = jnp.asarray(k1, jnp.int32)
    k2 = jnp.asarray(k2, jnp.int32)
    slot, found = ht_find(ht, k1, k2)
    found = found & ok
    return HashTable(
        k1=ht.k1.at[slot].set(jnp.where(found, TOMB, ht.k1[slot])),
        k2=ht.k2.at[slot].set(jnp.where(found, TOMB, ht.k2[slot])),
        val=ht.val.at[slot].set(jnp.where(found, 0, ht.val[slot])),
    )


def ht_contains(ht: HashTable, k1, k2) -> jax.Array:
    _, found = ht_find(ht, k1, k2)
    return found


def ht_live_mask(ht: HashTable) -> jax.Array:
    return ht.k1 >= 0


def ht_load(ht: HashTable) -> jax.Array:
    """Fraction of live slots (host-side maintenance signal)."""
    return jnp.mean(ht_live_mask(ht).astype(jnp.float32))


def ht_rebuild(ht: HashTable, prehashed: bool = False) -> HashTable:
    """Host-callable compaction: rehash live entries into a fresh table.

    Long fully-dynamic streams accumulate tombstones that stretch probe
    chains; production deployments call this between steps when
    ``ht_load + tombstone fraction`` crosses a threshold.

    ``prehashed`` MUST match how the table is probed (see
    ``_probe_start``): rebuilding a prehashed table with the default mix
    would relocate every entry off its probe chain.  (The router's intern
    tables are prehashed but never tombstone, so they never need this.)
    """
    fresh = ht_new(ht.capacity)

    def body(i, t):
        live = ht.k1[i] >= 0
        return jax.lax.cond(
            live,
            lambda t: ht_set(t, ht.k1[i], ht.k2[i], ht.val[i],
                             prehashed=prehashed),
            lambda t: t, t)

    return jax.lax.fori_loop(0, ht.capacity, body, fresh)
