"""Host-side wrappers around the batched engine (Tier B public API).

Two front-ends share the jitted step:

* :class:`BatchedSummarizer` — one engine on one device.
* :class:`ShardedSummarizer` — an edge-partitioned fleet of engines laid out
  over a 1-D device mesh via ``shard_map`` (one ``EngineState`` replica per
  partition, several replicas per device when ``n_shards`` exceeds the device
  count), merged into a :class:`ShardedSummaryOutput` on the host.  This is
  how the MoSSo engine scales past a single device's ``n_cap``.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterable, List, Optional, Sequence, Set, Tuple

import numpy as np

from repro.core.engine.state import EngineConfig, EngineState, new_state
from repro.core.engine.trial import make_step
from repro.core.summary import (ShardedSummaryOutput, SummaryOutput,
                                encoding_cost, is_superedge, pair_key)

Change = Tuple[int, int, bool]


# --------------------------------------------------------------------------- #
# state-level exports (shared by both front-ends; engine-id space)
# --------------------------------------------------------------------------- #


def state_live_edges(state: EngineState) -> Set[Tuple[int, int]]:
    """Export the live edge set from the slot-position table."""
    k1 = np.asarray(state.epos.k1)
    k2 = np.asarray(state.epos.k2)
    live = k1 >= 0
    return {(int(a), int(b)) for a, b in zip(k1[live], k2[live]) if a < b}


def state_materialize(state: EngineState,
                      cfg: EngineConfig | None = None) -> SummaryOutput:
    """Derive (G*, P, C+, C-) from counts + membership (optimal encoding).

    Decoding is lossless under EVERY objective — the encoding always
    reproduces exactly the live edge set.  The objective only decides
    which side of the per-pair superedge/corrections rule is cheaper:
    pass ``cfg`` so a weighted-objective state picks modes by
    ``is_superedge(W, TW)`` (the rule its ``phi`` was accounted under)
    instead of the unweighted counts.
    """
    weighted = cfg is not None and cfg.objective == "weighted"
    n2s = np.asarray(state.n2s)
    ssize = np.asarray(state.ssize)
    seen = n2s >= 0
    members: Dict[int, Set[int]] = {}
    for u in np.nonzero(seen)[0]:
        members.setdefault(int(n2s[u]), set()).add(int(u))
    for sid, mem in members.items():
        assert len(mem) == ssize[sid], f"ssize drift at sid {sid}"

    k1 = np.asarray(state.eab.k1)
    k2 = np.asarray(state.eab.k2)
    val = np.asarray(state.eab.val)
    live = k1 >= 0
    edges = state_live_edges(state)

    if weighted:
        from repro.core.reference.weights import host_node_weight
        wmap = {}
        wk1 = np.asarray(state.weab.k1)
        wlive = wk1 >= 0
        for a, b, w in zip(wk1[wlive], np.asarray(state.weab.k2)[wlive],
                           np.asarray(state.weab.val)[wlive]):
            wmap[(int(a), int(b))] = int(w)

        def w_of(u: int) -> int:
            return host_node_weight(u, cfg.weight_levels)

    superedges: Set[Tuple[int, int]] = set()
    c_plus: Set[Tuple[int, int]] = set()
    c_minus: Set[Tuple[int, int]] = set()
    for a, b, e in zip(k1[live], k2[live], val[live]):
        a, b, e = int(a), int(b), int(e)
        sa, sb = len(members[a]), len(members[b])
        t = sa * (sa - 1) // 2 if a == b else sa * sb
        pair_edges = [pq for pq in _pairs(members[a], members[b], a == b)]
        actual = [pq for pq in pair_edges if pq in edges]
        assert len(actual) == e, f"eab drift at pair {(a, b)}: {len(actual)} != {e}"
        if weighted:
            wab = wmap.get((a, b), 0)
            w_actual = sum(w_of(p) * w_of(q) for (p, q) in actual)
            assert w_actual == wab, \
                f"weab drift at pair {(a, b)}: {w_actual} != {wab}"
            tw = sum(w_of(p) * w_of(q) for (p, q) in pair_edges)
            mode_super = is_superedge(wab, tw)
        else:
            mode_super = is_superedge(e, t)
        if mode_super:
            superedges.add(pair_key(a, b))
            c_minus.update(pq for pq in pair_edges if pq not in edges)
        else:
            c_plus.update(actual)
    return SummaryOutput(supernodes=members, superedges=superedges,
                         c_plus=c_plus, c_minus=c_minus)


def state_phi_recomputed(state: EngineState,
                         cfg: EngineConfig | None = None) -> int:
    """Refold phi from the live pair table (weighted fold when ``cfg``
    selects the weighted objective)."""
    if cfg is not None and cfg.objective == "weighted":
        k1 = np.asarray(state.weab.k1)
        k2 = np.asarray(state.weab.k2)
        val = np.asarray(state.weab.val)
        wsum = np.asarray(state.wsum)
        wsq = np.asarray(state.wsq)
        live = k1 >= 0
        tot = 0
        for a, b, w in zip(k1[live], k2[live], val[live]):
            a, b = int(a), int(b)
            if a == b:
                tw = (int(wsum[a]) ** 2 - int(wsq[a])) // 2
            else:
                tw = int(wsum[a]) * int(wsum[b])
            tot += encoding_cost(int(w), tw)
        return tot
    k1 = np.asarray(state.eab.k1)
    k2 = np.asarray(state.eab.k2)
    val = np.asarray(state.eab.val)
    ssize = np.asarray(state.ssize)
    live = k1 >= 0
    tot = 0
    for a, b, e in zip(k1[live], k2[live], val[live]):
        a, b = int(a), int(b)
        sa, sb = int(ssize[a]), int(ssize[b])
        t = sa * (sa - 1) // 2 if a == b else sa * sb
        tot += encoding_cost(int(e), t)
    return tot


def _pairs(ma: Set[int], mb: Set[int], same: bool):
    if same:
        mem = sorted(ma)
        for i, u in enumerate(mem):
            for v in mem[i + 1:]:
                yield (u, v)
    else:
        for u in sorted(ma):
            for v in sorted(mb):
                yield (u, v) if u < v else (v, u)


def _relabel_output(out: SummaryOutput, rev: Sequence[object],
                    sid_offset: int) -> SummaryOutput:
    """Map a shard's engine-id output back to caller labels, with supernode
    ids offset into a globally unique range."""
    return SummaryOutput(
        supernodes={sid_offset + sid: {rev[u] for u in mem}
                    for sid, mem in out.supernodes.items()},
        superedges={(sid_offset + a, sid_offset + b)
                    for (a, b) in out.superedges},
        c_plus={pair_key(rev[a], rev[b]) for (a, b) in out.c_plus},
        c_minus={pair_key(rev[a], rev[b]) for (a, b) in out.c_minus},
    )


# --------------------------------------------------------------------------- #
# crash consistency (shared by both front-ends)
# --------------------------------------------------------------------------- #


class _CrashConsistency:
    """Epoch checkpoints + write-ahead chunk journal for a summarizer.

    Both front-ends dispatch the stream in fixed-size chunks
    (``dispatch_chunk``), and chunk boundaries fully determine padding
    and the engine-round/PRNG schedule — so a run is reconstructible
    bitwise from (checkpoint at epoch E) + (the exact chunk slices
    dispatched after E).  This mixin supplies that contract:

    * with ``checkpoint_dir`` set, every chunk is durably journaled
      (:class:`repro.checkpoint.journal.ChunkJournal`) **before** it is
      dispatched;
    * ``save()`` writes the full recovery closure at a flushed epoch and
      compacts the journal; ``restore()`` loads the newest checkpoint
      that passes its checksums (refusing config mismatches);
    * ``recover()`` = restore + deterministic journal-tail replay, the
      crash path proven bitwise by ``tests/test_recovery.py``.

    ``stream_cursor`` counts stream changes applied so far — a driver
    resumes feeding from there after ``recover()``.  ``_incarnation``
    bumps on every restore so pinned query views fail loudly instead of
    resolving labels against a state they were not snapshotted from.
    """

    def _init_crash_consistency(self, checkpoint_dir: Optional[str]) -> None:
        self._ckpt_dir = checkpoint_dir
        self._journal = None        # lazily opened ChunkJournal
        self._journal_seq = 0       # chunks dispatched (journal record seq)
        self._cursor = 0            # stream changes applied
        self._replaying = False     # recovery replay: don't re-journal
        self._recovered = False     # this instance resumed an old directory
        self.stream_retries = 0     # recoveries performed by a retry driver
        self._incarnation = 0       # bumps per restore; query views pin it

    @property
    def stream_cursor(self) -> int:
        """Stream changes applied (journaled-and-dispatched) so far."""
        return self._cursor

    def _journal_chunk(self, chunk) -> None:
        """WAL append for one dispatch chunk; seq advances regardless of
        whether journaling is enabled so save/restore counters line up."""
        seq = self._journal_seq
        self._journal_seq += 1
        if self._ckpt_dir is None or self._replaying:
            return
        if self._journal is None:
            from repro.checkpoint.journal import ChunkJournal
            from repro.checkpoint.summary import journal_path
            self._journal = ChunkJournal(journal_path(self._ckpt_dir))
            if seq == 0 and not self._recovered:
                self._journal.reset()   # fresh stream into an old directory
        self._journal.append(seq, chunk)

    def _replay_chunk(self, changes) -> None:
        """Re-dispatch one journaled chunk during recovery (no re-append).
        Each journal record is one original dispatch slice (≤ the chunk
        size), so replaying it as its own ``process`` call reproduces the
        original padding and engine-round schedule exactly."""
        self._replaying = True
        try:
            self.process(changes)
        finally:
            self._replaying = False

    def _require_ckpt_dir(self, ckpt_dir: Optional[str]) -> str:
        d = ckpt_dir or self._ckpt_dir
        if d is None:
            raise ValueError(
                "no checkpoint directory: pass one explicitly or construct "
                "the summarizer with checkpoint_dir=...")
        return d

    def save(self, ckpt_dir: Optional[str] = None) -> str:
        """Checkpoint the full recovery closure at a flushed epoch."""
        from repro.checkpoint import summary as ckpt
        return ckpt.save_summarizer(self, self._require_ckpt_dir(ckpt_dir))

    def restore(self, ckpt_dir: Optional[str] = None,
                step: Optional[int] = None) -> dict:
        """Load the newest verifiable checkpoint (or ``step``) into this
        summarizer; raises on config mismatch, falls back across corrupt
        epochs."""
        from repro.checkpoint import summary as ckpt
        return ckpt.restore_summarizer(self, self._require_ckpt_dir(ckpt_dir),
                                       step=step)

    def recover(self, ckpt_dir: Optional[str] = None) -> dict:
        """Crash recovery: restore last valid epoch + replay journal tail."""
        from repro.checkpoint import summary as ckpt
        return ckpt.recover_summarizer(self, self._require_ckpt_dir(ckpt_dir))


# --------------------------------------------------------------------------- #
# single-engine front-end
# --------------------------------------------------------------------------- #


class BatchedSummarizer(_CrashConsistency):
    """Feed a fully dynamic graph stream through the jitted engine step.

    **Id space.** ``process``/``run`` accept arbitrary hashable caller
    labels and intern them (host-side, encounter order) into the engine's
    dense ``[0, n_cap)`` id space.  Outputs stay in ENGINE ids:
    ``live_edges``/``materialize``/``phi_recomputed`` report engine-id
    pairs; map engine ids back to labels through ``self._rev`` (or map a
    label-space ground truth into engine ids through ``self._ids``) when
    comparing — the sharded front-end, by contrast, reports caller labels.

    **Capacity.** One engine, one device: at most ``n_cap`` distinct
    labels ever seen (asserted at interning time) and ``m_cap`` live edges
    (a table-sizing contract, unchecked — see :class:`EngineConfig`).
    Scale past either with :class:`ShardedSummarizer`.

    **Probe backend.** ``trial_backend`` selects how the step's batched
    hash-table probes lower: ``"xla"`` (vmapped while loops, the
    differential reference) or ``"pallas"`` (one fused kernel launch per
    probe batch, ``repro.kernels.ht_probe``; interpret mode off-TPU).
    ``None`` defers to the ``REPRO_TRIAL_BACKEND`` env default.  Both
    backends are leaf-bitwise state-identical on identical streams.
    """

    def __init__(self, cfg: EngineConfig | None = None, *,
                 trial_backend: str | None = None,
                 checkpoint_dir: Optional[str] = None, **overrides) -> None:
        from repro.core.engine.hashtable import resolve_trial_backend
        if cfg is None:
            cfg = EngineConfig(**overrides)
        elif overrides:
            cfg = dataclasses.replace(cfg, **overrides)
        self.cfg = cfg
        self.trial_backend = resolve_trial_backend(trial_backend)
        self.state: EngineState = new_state(cfg)
        self._step = make_step(cfg, trial_backend=self.trial_backend)
        self._ids: Dict[object, int] = {}
        self._rev: List[object] = []
        self._epoch = 0             # engine-step dispatches applied so far
        self._init_crash_consistency(checkpoint_dir)

    # ------------------------------------------------------------------ ids
    def _nid(self, label: object) -> int:
        i = self._ids.get(label)
        if i is None:
            i = len(self._rev)
            assert i < self.cfg.n_cap, "node capacity exceeded"
            self._ids[label] = i
            self._rev.append(label)
        return i

    # --------------------------------------------------------------- stream
    @property
    def dispatch_chunk(self) -> int:
        """Stream slice size per journaled dispatch (= ``cfg.batch``)."""
        return self.cfg.batch

    def process(self, changes: Sequence[Change]) -> None:
        b = self.cfg.batch
        changes = list(changes)
        # slice BEFORE interning: each batch slice is journaled (WAL) and
        # then interned+dispatched on its own, so a journal-tail replay of
        # the same slices reproduces _ids encounter order, padding and the
        # engine-round/PRNG schedule exactly (interning is stream-ordered
        # either way, so per-slice interning is bitwise identical to the
        # old whole-call interning)
        for off in range(0, len(changes), b):
            sl = changes[off:off + b]
            self._journal_chunk(sl)
            buf = [(self._nid(u), self._nid(v), ins) for (u, v, ins) in sl]
            pad = b - len(buf)
            u = np.array([c[0] for c in buf] + [-1] * pad, np.int32)
            v = np.array([c[1] for c in buf] + [-1] * pad, np.int32)
            ins = np.array([c[2] for c in buf] + [False] * pad, bool)
            self.state = self._step(self.state, u, v, ins)
            self._epoch += 1
            self._cursor += len(sl)

    def run(self, stream: Iterable[Change]) -> "BatchedSummarizer":
        self.process(list(stream))
        return self

    def flush(self) -> None:
        """No-op barrier (dispatch is synchronous here); API symmetry with
        the sharded tier so checkpoint code can flush either."""

    # ---------------------------------------------------------------- reads
    @property
    def flush_epoch(self) -> int:
        """Engine-step dispatches applied to ``state`` so far.  The state
        pytree is replaced functionally per dispatch, so a reference
        captured between ``process`` calls is exactly this epoch's state."""
        return self._epoch

    def query(self):
        """Snapshot read view answering ``neighbors``/``degree``/
        ``has_edge`` in caller-label space directly from the compressed
        engine state — no decompression (:mod:`repro.serve.query`).
        Labels streamed after this call raise ``LookupError`` on the view.
        """
        from repro.serve.query import SummaryQuery
        return SummaryQuery(self)

    # ------------------------------------------------------------ maintenance
    def table_pressure(self) -> Dict[str, float]:
        """live+tombstone slot fraction per table (probe-chain health)."""
        from repro.core.engine.hashtable import TOMB
        out = {}
        tables = ("adj", "epos", "eab", "snadj", "snpos")
        if self.cfg.objective == "weighted":
            tables += ("weab",)
        for name in tables:
            t = getattr(self.state, name)
            k1 = np.asarray(t.k1)
            out[name] = float(((k1 >= 0) | (k1 == int(TOMB))).mean())
        return out

    def maybe_compact(self, threshold: float = 0.7) -> bool:
        """Rebuild tables whose occupied fraction (live + tombstones) crosses
        ``threshold``.  Long fully-dynamic streams accumulate tombstones that
        stretch linear-probe chains; production deployments call this between
        steps (it is pure state -> state, so it composes with checkpoints).
        """
        from repro.core.engine.hashtable import ht_rebuild
        pressure = self.table_pressure()
        dirty = {n: p for n, p in pressure.items() if p > threshold}
        if not dirty:
            return False
        self.state = self.state._replace(
            **{n: ht_rebuild(getattr(self.state, n)) for n in dirty})
        return True

    # ---------------------------------------------------------------- stats
    @property
    def phi(self) -> int:
        return int(self.state.phi)

    @property
    def num_edges(self) -> int:
        return int(self.state.num_edges)

    def compression_ratio(self) -> float:
        e = self.num_edges
        return float(self.phi) / e if e else 0.0

    def stats(self) -> dict:
        s = self.state
        return dict(phi=int(s.phi), num_edges=int(s.num_edges),
                    trials=int(s.n_trials), accepted=int(s.n_accept),
                    skipped=int(s.n_skipped),
                    stream_retries=self.stream_retries)

    # ----------------------------------------------------- recovery closure
    def _ckpt_tree(self) -> dict:
        return {"est": self.state._asdict()}

    def _ckpt_host(self) -> dict:
        return {"ids": dict(self._ids), "rev": list(self._rev)}

    def _ckpt_manifest(self) -> dict:
        return {"tier": "batched", "config": self.cfg.manifest(),
                "trial_backend": self.trial_backend}

    @staticmethod
    def _ckpt_pins() -> tuple:
        # trial_backend is a bitwise-identical execution variant (standing
        # differential bar) — recorded, not pinned
        return ("tier", "config")

    def _ckpt_apply(self, tree: dict, host: dict, extra: dict) -> None:
        self.state = EngineState(**tree["est"])
        self._ids = dict(host["ids"])
        self._rev = list(host["rev"])
        self._epoch = int(extra["epoch"])
        self._journal_seq = int(extra["journal_seq"])
        self._cursor = int(extra["cursor"])
        self._recovered = True
        self._incarnation += 1

    # ------------------------------------------------------------ materialize
    def live_edges(self) -> Set[Tuple[int, int]]:
        return state_live_edges(self.state)

    def materialize(self) -> SummaryOutput:
        return state_materialize(self.state, self.cfg)

    def phi_recomputed(self) -> int:
        return state_phi_recomputed(self.state, self.cfg)


# --------------------------------------------------------------------------- #
# sharded front-end
# --------------------------------------------------------------------------- #


class ShardedSummarizer(_CrashConsistency):
    """Edge-partitioned summarization across mesh devices.

    Every stream change is routed to the shard owning its canonical pair
    (``min(h(u), h(v)) % n_shards`` over the stable 62-bit label hash
    ``h``, :mod:`repro.dist.labelhash`), so each engine replica sees a
    deterministic, disjoint edge partition and summarizes it losslessly on
    its own ``n_cap``-bounded id space.  Aggregate capacity therefore grows
    linearly with the shard count.  The merged output is the union-of-parts
    encoding (:class:`ShardedSummaryOutput`); ``phi`` is the sum of shard
    phis since per-pair encodings never span shards.

    **Id spaces.** Three layers, all host-recoverable:

    * caller labels — any hashable (streaming) / mutually orderable
      (``live_edges``/``materialize``) values;
    * 62-bit label hashes — a pure stable function of the label
      (splitmix64 for ints, blake2b-8 otherwise), carried on device as two
      31-bit words; the routing key is computed on hashes, so placement
      needs no host dict and no encounter-order state;
    * per-shard local nids — dense ``[0, n_cap)`` ids the engine state is
      indexed by, assigned ON DEVICE in delivery order by the intern tables
      of :mod:`repro.dist.router` (both routing modes assign identically).

    The hash -> label reverse map needed by ``decode``/``materialize``/
    ``shard_of`` is folded lazily at sync points from a per-chunk label
    buffer — never on the dispatch path.  A (astronomically unlikely)
    62-bit hash collision is detected at the fold and raises rather than
    silently merging two nodes.

    **Routing modes** (``routing=``):

    * ``"device"`` (default) — changes stream through the two-stage
      jit-compiled router: the **route** stage (shard keys + a
      capacity-bounded ``all_to_all`` lane exchange, run as a bounded
      on-device drain loop when a (source, shard) lane exceeds
      ``lane_cap``) depends only on the chunk, and the **engine** stage
      (on-device interning + pmax-agreed engine rounds) carries the state.
      With the default ``max_drain_rounds`` delivery of a full chunk is
      statically guaranteed, so dispatch is **sync-free**, and the two
      stages form a software pipeline: chunk k+1 is hashed, packed and
      routed (drain rounds included) while chunk k runs its engine rounds
      (``pipeline=False`` forces serial per-chunk dispatch, bit-identical
      results).  Only an explicitly lowered ``max_drain_rounds`` (or
      ``chunk_sync=True``) reinstates the per-chunk watermark fetch; a
      suffix left undelivered when the round budget runs out falls back to
      the host path below and ``router_overflows`` counts the spilled
      changes.
    * ``"host"`` — the differential reference: the host buckets hashed
      changes per shard (vectorized numpy, stream order preserved) and
      feeds padded ``[n_shards, batch]`` rounds.  Given identical
      ``process`` call boundaries (calls no longer than ``router_chunk``),
      both modes produce bit-identical engine states — including through
      multi-round drains — as long as no host fallback ran (the fallback
      legitimately shifts the PRNG schedule).

    **Replica execution** (``replica_exec=``): how the shard replicas
    stacked on one device (``n_shards > n_devices``, the production
    layout) are laid out inside the compiled step:

    * ``"vmap"`` — one batched program over the stacked replica axis.
      The trial engine is cond-free predicated data flow, so vmap pays
      no both-branches penalty and the engine stage becomes one
      replica-parallel step.
    * ``"map"`` — ``lax.map`` over replicas, serializing them per
      device.  Also the differential reference, like ``routing="host"``:
      both modes are leaf-bitwise state-identical on identical inputs.

    The default (``repro.dist.router.DEFAULT_REPLICA_EXEC``) is
    backend-aware: vmap on accelerators, map on the XLA CPU backend,
    where batched control flow carries a measured fixed dispatch tax
    (see docs/KNOWN_ISSUES.md).  ``REPRO_REPLICA_EXEC`` overrides.

    **Probe backend** (``trial_backend=``): how the engine's batched
    hash-table probes (trial lookups + the router's intern pre-lookup)
    lower — ``"xla"`` (vmapped while loops; the default and the
    differential reference) or ``"pallas"`` (one fused
    ``repro.kernels.ht_probe`` launch per batch; interpret mode off-TPU).
    ``REPRO_TRIAL_BACKEND`` sets the process default; both backends are
    leaf-bitwise state-identical.

    **Routing telemetry.** ``router_syncs`` counts per-chunk watermark
    fetches (0 when ``sync_free``), ``router_host_dict_ops`` counts
    label-map mutations performed inside dispatch (0 on the hash-routed
    steady state — the reverse map folds lazily at sync points),
    ``router_overflows`` counts changes replayed through the host path,
    and ``stats()['router_drain_rounds']`` counts extra drain rounds
    beyond the first (carried in the engine stage's device-side state —
    the route stage's round count rides into the engine step, which
    accumulates it on device; fetched only at sync points, with zero
    host-side buffering of per-chunk counts).

    **Capacity semantics.** Edge partitioning is a vertex cut: a node
    touching edges in several partitions occupies a local id in each, so
    per-shard ``n_cap`` must budget the replication factor (see
    ``src/repro/dist/README.md``).  The host path and the device path both
    intern on device; exceeding ``n_cap`` increments a per-shard
    ``n_dropped`` counter and skips the change, and the next host-side
    sync point (``phi``/``stats``/``materialize``/...) raises
    ``RuntimeError`` — a dropped change would otherwise silently break
    losslessness.
    """

    def __init__(self, cfg: EngineConfig | None = None, *,
                 mesh=None, n_shards: Optional[int] = None,
                 routing: str = "device", router_chunk: int = 1024,
                 lane_cap: Optional[int] = None,
                 max_drain_rounds: Optional[int] = None,
                 chunk_sync: bool = False,
                 pipeline: bool = True,
                 replica_exec: Optional[str] = None,
                 trial_backend: Optional[str] = None,
                 checkpoint_dir: Optional[str] = None,
                 **overrides) -> None:
        import math

        import jax
        import jax.numpy as jnp

        from repro.dist import router as dist_router

        if cfg is None:
            cfg = EngineConfig(**overrides)
        elif overrides:
            cfg = dataclasses.replace(cfg, **overrides)
        self.cfg = cfg
        if replica_exec is None:
            replica_exec = dist_router.DEFAULT_REPLICA_EXEC
        if replica_exec not in dist_router.REPLICA_EXEC_MODES:
            raise ValueError(
                f"replica_exec must be one of "
                f"{dist_router.REPLICA_EXEC_MODES}: {replica_exec}")
        self.replica_exec = replica_exec
        from repro.core.engine.hashtable import resolve_trial_backend
        self.trial_backend = resolve_trial_backend(trial_backend)
        if mesh is None:
            from repro.launch.mesh import make_engine_mesh
            if n_shards is None:
                mesh = make_engine_mesh()
            else:
                # fit the mesh to the shard count: n_shards replicas spread
                # over the largest local device subset that divides them
                mesh = make_engine_mesh(
                    math.gcd(int(n_shards), len(jax.devices())))
        self.mesh = mesh
        n_dev = int(mesh.devices.size)
        self.n_shards = n_dev if n_shards is None else int(n_shards)
        if self.n_shards % n_dev != 0:
            raise ValueError(
                f"n_shards={self.n_shards} must be a multiple of the mesh "
                f"device count {n_dev}")
        if self.n_shards >= dist_router.MAX_SHARDS:
            raise ValueError(
                f"n_shards={self.n_shards} must be < "
                f"{dist_router.MAX_SHARDS} (device shard keys compose "
                f"31-bit hash words over uint32 residues)")
        if routing not in ("device", "host"):
            raise ValueError(f"routing must be 'device' or 'host': {routing}")
        self.routing = routing
        # round the chunk up so it splits evenly over the devices
        self.router_chunk = -(-int(router_chunk) // n_dev) * n_dev
        self.lane_cap = (dist_router.default_lane_cap(
            self.router_chunk, n_dev, self.n_shards, cfg.batch)
            if lane_cap is None
            else min(int(lane_cap), self.router_chunk // n_dev))
        self.router_overflows = 0   # changes spilled to the host path
        self.router_syncs = 0       # per-chunk watermark fetches performed
        self.chunk_sync = bool(chunk_sync)
        # drain-round telemetry lives IN the engine stage's carried state
        # (int32[n_dev], accumulated on device, fetched only at sync points)
        self._drain_rounds = dist_router.drain_telemetry_new(n_dev)
        self._bucketed = dist_router.make_bucketed_step(
            cfg, mesh, replica_exec, self.trial_backend)
        if routing == "device":
            self._route, self.router_geometry = dist_router.make_route_step(
                mesh, self.n_shards, self.router_chunk, self.lane_cap,
                max_drain_rounds)
            self._engine = dist_router.make_engine_step(
                cfg, mesh, self.n_shards, self.router_geometry.acc_cap,
                replica_exec, self.trial_backend)
            self.lane_cap = self.router_geometry.lane_cap
            self.max_drain_rounds = self.router_geometry.max_drain_rounds
            # delivery statically guaranteed -> the overflow watermark never
            # gates anything and dispatch needs no per-chunk host round-trip
            self.sync_free = (self.router_geometry.drain_guaranteed
                              and not self.chunk_sync)
        else:
            self._route = self._engine = None
            self.router_geometry = None
            self.max_drain_rounds = None
            self.sync_free = False
        # the route stage has no state dependencies, so on the sync-free
        # path chunk k+1's routing is dispatched while chunk k's engine
        # rounds execute (one routed chunk in flight, flushed at sync)
        self.pipeline = bool(pipeline) and self.sync_free
        self._pending = None        # routed buckets awaiting engine dispatch
        self._epoch = 0             # engine dispatches applied to self.state
        self._init_crash_consistency(checkpoint_dir)

        state1 = new_state(cfg)
        n = self.n_shards
        stacked = jax.tree.map(
            lambda l: jnp.broadcast_to(l[None], (n,) + l.shape), state1)
        # decorrelate the per-shard trial PRNG streams
        stacked = stacked._replace(
            step_no=jnp.uint32(cfg.seed)
            + jnp.arange(n, dtype=jnp.uint32) * jnp.uint32(2654435761))
        self.state = stacked
        ist1 = dist_router.intern_new(cfg)
        self.intern = jax.tree.map(
            lambda l: jnp.broadcast_to(l[None], (n,) + l.shape), ist1)

        self._h2label: Dict[int, object] = {}  # 62-bit hash -> caller label
        self._label_buf: List = []   # (labels, hi, lo) pending lazy fold
        self._label_head = None      # compacted (labels, hashes), hash-sorted
        self._host_dict_ops = 0      # label-map mutations inside dispatch
        self._in_dispatch = False
        self._host_cache = None

    # ------------------------------------------------------------------ ids
    def _pack_chunk(self, chunk: Sequence[Change], pad_to: int = 0):
        """Hash one chunk of labeled changes into device words.

        One vectorized numpy pass for integer labels (no per-change Python
        object work), a pure per-element hash otherwise — either way zero
        dict mutations; the labels are buffered for the lazy reverse-map
        fold at the next sync point.
        """
        from repro.dist import labelhash

        m = len(chunk)
        us = [c[0] for c in chunk]
        vs = [c[1] for c in chunk]
        uh, ul = labelhash.hash_words(us)
        vh, vl = labelhash.hash_words(vs)
        fl = np.fromiter((c[2] for c in chunk), np.int32, m)
        self._label_buf.append((us, uh, ul))
        self._label_buf.append((vs, vh, vl))
        if pad_to > m:
            def pad(a, fill):
                return np.concatenate(
                    [a, np.full(pad_to - m, fill, a.dtype)])
            uh, ul, vh, vl = (pad(a, -1) for a in (uh, ul, vh, vl))
            fl = pad(fl, 0)
        return uh, ul, vh, vl, fl

    @staticmethod
    def _collision(a, b, h) -> "RuntimeError":
        return RuntimeError(
            f"62-bit label-hash collision: {a!r} and {b!r} both hash to "
            f"{int(h):#x}; rename one label (collision odds are ~n^2/2^63 "
            f"— this is loud instead of silently merging the two nodes)")

    def _compact_label_buf(self) -> None:
        """Dedup the pending label buffer by hash — numpy only, no dict.

        Without this a long zero-sync run would buffer every label
        OCCURRENCE (two per change) until the next fold.  Compaction
        dedups the un-compacted tail (object-array work proportional to
        the tail only) and merges it into a hash-sorted compacted head
        with pure int64 numpy ops, so the buffer is bounded at O(unique
        labels) and per-cycle Python-object work at O(compaction window).
        Dropped duplicates are equality-checked against the kept first
        occurrence (vectorized object compare), so a hash collision still
        raises loudly here rather than being silently compacted away."""
        from repro.dist import labelhash

        buf = self._label_buf
        if not buf:
            return
        labels = [x for (ls, _, _) in buf for x in ls]
        arr = np.array(labels, dtype=object)
        if arr.ndim != 1:           # e.g. equal-length tuple labels
            arr = np.empty(len(labels), object)
            for i, x in enumerate(labels):
                arr[i] = x
        comb = np.concatenate([labelhash.combine(hi, lo)
                               for (_, hi, lo) in buf])
        uniq, first, inv = np.unique(comb, return_index=True,
                                     return_inverse=True)
        # identity escape mirrors _fold_labels' `prev is not label`: a
        # non-reflexive label (NaN) must not read as a self-collision
        same = arr == arr[first[inv]]
        for i in np.flatnonzero(~np.asarray(same, bool)):
            j = int(first[inv[int(i)]])
            if arr[int(i)] is not arr[j]:
                raise self._collision(arr[j], arr[int(i)], comb[int(i)])
        keep = arr[first]
        if self._label_head is None:
            self._label_head = (keep, uniq)
        else:
            h_lab, h_hash = self._label_head
            pos = np.searchsorted(h_hash, uniq)
            posc = np.minimum(pos, len(h_hash) - 1)
            known = (pos < len(h_hash)) & (h_hash[posc] == uniq)
            if bool(np.any(known)):
                same2 = keep[known] == h_lab[posc[known]]
                kidx = np.flatnonzero(known)
                for k in np.flatnonzero(~np.asarray(same2, bool)):
                    i = int(kidx[int(k)])
                    if keep[i] is not h_lab[int(posc[i])]:
                        raise self._collision(h_lab[int(posc[i])], keep[i],
                                              uniq[i])
            fresh = ~known
            m_hash = np.concatenate([h_hash, uniq[fresh]])
            order = np.argsort(m_hash)       # disjoint hashes: total order
            self._label_head = (
                np.concatenate([h_lab, keep[fresh]])[order], m_hash[order])
        buf.clear()

    def _fold_labels(self) -> None:
        """Fold buffered/compacted labels into the hash -> label map.

        Runs at sync points (``materialize``/``shard_of``/``stats``/...),
        never on the steady-state dispatch path: no dispatch code calls
        this by construction, and ``router_host_dict_ops`` is the runtime
        tripwire proving it — any future code path that folds (mutates
        the label map) while ``process()`` is dispatching gets counted,
        and the `== 0` assertions in tests/benchmarks/example go red.
        Raises on a 62-bit hash collision between distinct labels:
        placement and interning key on the hash, so a collision would
        silently merge two nodes — loud failure is the contract.
        """
        head, buf = self._label_head, self._label_buf
        if head is None and not buf:
            return
        from repro.dist import labelhash

        if self._in_dispatch:
            self._host_dict_ops += (
                (len(head[0]) if head is not None else 0)
                + sum(len(e[0]) for e in buf))
        h2l = self._h2label
        entries = ([] if head is None
                   else [(head[0].tolist(), head[1])])
        entries += [(labels, labelhash.combine(hi, lo))
                    for (labels, hi, lo) in buf]
        for labels, comb in entries:
            for label, h in zip(labels, comb.tolist()):
                prev = h2l.setdefault(h, label)
                if prev is not label and prev != label:
                    raise self._collision(prev, label, h)
        self._label_head = None
        buf.clear()

    def host_label_map(self) -> Dict[int, object]:
        """The folded 62-bit hash -> caller label map (host side).

        A sync point: drains the dispatch pipeline and folds any buffered
        chunk labels first, so this plus ``state``/``intern`` really is
        everything a checkpoint needs to resume decoding.  The returned
        dict is live state — treat it as read-only."""
        self._flush_dispatch()
        self._fold_labels()
        return self._h2label

    def shard_of(self, u: object, v: object) -> int:
        """Deterministic owner shard of a STREAMED edge {u, v}.

        Placement is a pure function of the label hashes, so the answer
        never depends on stream order; the method still raises
        ``LookupError`` for labels this summarizer has not seen, keeping
        "has this node been streamed" queryable (and typos loud).
        Read-only: consults the lazily-folded reverse map, assigns
        nothing.
        """
        from repro.dist import labelhash

        self._fold_labels()
        hu, hv = labelhash.hash_label(u), labelhash.hash_label(v)
        for label, h in ((u, hu), (v, hv)):
            if h not in self._h2label:
                raise LookupError(
                    f"shard_of: label {label!r} has not been streamed")
        return min(hu, hv) % self.n_shards

    # --------------------------------------------------------------- stream
    def process(self, changes: Sequence[Change]) -> None:
        """Apply a sequence of changes, ``router_chunk`` at a time.

        Both routing modes consume the same chunk boundaries, so a host- and
        a device-routed run fed identical calls stay comparable change for
        change.  On the sync-free device path the last chunk's engine stage
        may still be in flight when this returns (jax async dispatch +
        the route/engine pipeline); every state accessor flushes first.
        """
        changes = list(changes)
        self._in_dispatch = True
        try:
            for off in range(0, len(changes), self.router_chunk):
                chunk = changes[off:off + self.router_chunk]
                self._journal_chunk(chunk)      # durable BEFORE dispatch
                if self.routing == "device":
                    self._process_chunk_device(chunk)
                else:
                    self._process_chunk_host(chunk)
                self._cursor += len(chunk)
        finally:
            self._in_dispatch = False

    @property
    def dispatch_chunk(self) -> int:
        """Stream slice size per journaled dispatch (= ``router_chunk``)."""
        return self.router_chunk

    def _process_chunk_host(self, chunk: Sequence[Change]) -> None:
        """Host routing: bucket hashed changes per shard, feed padded
        rounds.  Vectorized (stable ``flatnonzero`` order == stream
        order); shares the packing/hashing path with the device router so
        the two modes see identical keys."""
        from repro.dist import labelhash

        self._flush_dispatch()
        n, b = self.n_shards, self.cfg.batch
        uh, ul, vh, vl, fl = self._pack_chunk(chunk)
        dest = np.minimum(labelhash.combine(uh, ul),
                          labelhash.combine(vh, vl)) % n
        idxs = [np.flatnonzero(dest == s) for s in range(n)]
        rounds = (max((len(i) for i in idxs), default=0) + b - 1) // b
        for r in range(rounds):
            buh = np.full((n, b), -1, np.int32)
            bul = np.full((n, b), -1, np.int32)
            bvh = np.full((n, b), -1, np.int32)
            bvl = np.full((n, b), -1, np.int32)
            bfl = np.zeros((n, b), np.int32)
            for s, idx in enumerate(idxs):
                sel = idx[r * b:(r + 1) * b]
                k = len(sel)
                if k:
                    buh[s, :k], bul[s, :k] = uh[sel], ul[sel]
                    bvh[s, :k], bvl[s, :k] = vh[sel], vl[sel]
                    bfl[s, :k] = fl[sel]
            self.state, self.intern = self._bucketed(
                self.state, self.intern, buh, bul, bvh, bvl, bfl)
        self._epoch += 1
        self._host_cache = None
        if len(self._label_buf) >= 128:
            self._compact_label_buf()

    def _process_chunk_device(self, chunk: Sequence[Change]) -> None:
        """Device routing: route stage + engine stage, software-pipelined.

        In the default (``sync_free``) configuration this method performs
        ZERO device-to-host transfers and ZERO host dict operations: the
        chunk is hashed in one vectorized pass, the route dispatch returns
        immediately (jax async dispatch), and the engine stage for the
        PREVIOUS chunk is dispatched after it — so chunk k+1's routing
        (drain rounds included) overlaps chunk k's engine rounds, with the
        routed buckets as donated double buffers.  Drain-round telemetry
        accumulates as a lazy device scalar fetched only at sync points.
        Only when the drain budget is explicitly bounded
        (``max_drain_rounds`` below the delivery guarantee) or
        ``chunk_sync=True`` does the watermark get fetched per chunk,
        gating the host-path replay of an undelivered suffix so stream
        order — and therefore losslessness — is preserved (serial
        dispatch: the pipeline needs the delivery guarantee)."""
        packed = self._pack_chunk(chunk, pad_to=self.router_chunk)
        *buckets, counts, delivered, rounds = self._route(*packed)
        # the route stage's round count rides into the engine stage, which
        # folds it into the carried device-side telemetry — no host-side
        # buffering of per-chunk drain counts at all
        routed = (*buckets, counts, rounds)
        self._host_cache = None
        # the label buffer compacts to unique hashes every 128 entries
        # (numpy only: no device fetch, no host dict ops)
        if len(self._label_buf) >= 128:
            self._compact_label_buf()
        if self.pipeline:
            prev, self._pending = self._pending, routed
            if prev is not None:
                self.state, self.intern, self._drain_rounds = self._engine(
                    self.state, self.intern, self._drain_rounds, *prev)
                self._epoch += 1
            return
        self.state, self.intern, self._drain_rounds = self._engine(
            self.state, self.intern, self._drain_rounds, *routed)
        self._epoch += 1
        if self.sync_free:
            return                           # statically fully delivered
        self.router_syncs += 1
        i0 = int(np.asarray(delivered).min())  # per-chunk sync (fallback gate)
        if i0 < len(chunk):
            self.router_overflows += len(chunk) - i0
            self._process_chunk_host(chunk[i0:])

    def _flush_dispatch(self) -> None:
        """Dispatch the engine stage for a still-pending routed chunk.

        Device-side only — never fetches — so the sync-free contract
        holds; sync points call this before reading any state."""
        if self._pending is not None:
            prev, self._pending = self._pending, None
            self.state, self.intern, self._drain_rounds = self._engine(
                self.state, self.intern, self._drain_rounds, *prev)
            self._epoch += 1

    def flush(self) -> None:
        """Public barrier: drain the dispatch pipeline (device-side only).

        After this, ``state``/``intern`` reflect every processed change;
        useful before checkpointing the raw device state."""
        self._flush_dispatch()

    def run(self, stream: Iterable[Change]) -> "ShardedSummarizer":
        self.process(list(stream))
        return self

    # ---------------------------------------------------------------- reads
    @property
    def flush_epoch(self) -> int:
        """Engine dispatches applied to ``state``/``intern`` so far — the
        flushed-epoch counter query snapshots pin.  On the pipelined path
        this trails the chunks handed to ``process`` by the one routed
        chunk still awaiting its engine stage."""
        return self._epoch

    def query(self, copy: bool = False):
        """Snapshot read view answering ``neighbors``/``degree``/
        ``has_edge`` in caller-label space from the live per-shard states
        — hash-placed fan-out, answers merged across shards, NO pipeline
        flush and NO decompression (:mod:`repro.serve.query`).  The view
        is pinned to ``flush_epoch``; on buffer-donating backends pass
        ``copy=True`` to keep it valid past the next ``process`` call
        (docs/KNOWN_ISSUES.md)."""
        from repro.serve.query import ShardedSummaryQuery
        return ShardedSummaryQuery(self, copy=copy)

    # ---------------------------------------------------------------- stats
    def host_states(self) -> List[EngineState]:
        """All shard engine states as host arrays: one device transfer,
        memoized until the next ``process`` call mutates the device state.
        Engine states index nodes by per-shard local nid."""
        return self._host_fetch()[0]

    def host_interns(self) -> List["object"]:
        """Per-shard intern states (hash <-> local nid maps) on the host."""
        return self._host_fetch()[1]

    def _host_fetch(self):
        self._flush_dispatch()
        if self._host_cache is None:
            import jax
            est, ist = jax.device_get((self.state, self.intern))
            self._host_cache = (
                [jax.tree.map(lambda x: x[s], est)
                 for s in range(self.n_shards)],
                [jax.tree.map(lambda x: x[s], ist)
                 for s in range(self.n_shards)])
        self._check_capacity()
        return self._host_cache

    def _check_capacity(self) -> None:
        self._flush_dispatch()
        if self._host_cache is not None:   # free: counters already fetched
            dropped = sum(int(i.n_dropped) for i in self._host_cache[1])
        else:
            dropped = int(np.asarray(self.intern.n_dropped).sum())
        self._raise_if_dropped(dropped)

    def _raise_if_dropped(self, dropped: int) -> None:
        if dropped:
            raise RuntimeError(
                f"node capacity exceeded: {dropped} endpoint interns dropped "
                f"(per-shard n_cap={self.cfg.n_cap}; raise n_cap or n_shards "
                f"— losslessness does not hold for the dropped changes)")

    def _shard_rev(self, shard: int) -> List[object]:
        """nid -> caller label for one shard: the device intern table's
        ``l2h`` rows through the lazily-folded hash -> label map."""
        from repro.dist import labelhash

        self._fold_labels()
        ist = self.host_interns()[shard]
        n = int(ist.n_nodes)
        l2h = np.asarray(ist.l2h)[:n]
        return [self._h2label[int(h)]
                for h in labelhash.combine(l2h[:, 0], l2h[:, 1])]

    def shard_state(self, shard: int) -> EngineState:
        return self.host_states()[shard]

    def shard_phis(self) -> List[int]:
        self._check_capacity()
        return [int(x) for x in np.asarray(self.state.phi)]

    @property
    def phi(self) -> int:
        """Global objective: sum of shard phis (per-pair encodings never
        span shards, so the union-of-parts cost is exactly additive)."""
        return sum(self.shard_phis())

    @property
    def num_edges(self) -> int:
        self._check_capacity()
        return int(np.asarray(self.state.num_edges).sum())

    def compression_ratio(self) -> float:
        e = self.num_edges
        return float(self.phi) / e if e else 0.0

    def stats(self) -> dict:
        """Aggregate engine counters plus routing telemetry:
        ``router_overflows`` counts changes that spilled from the device
        router back to the host path (only possible with an explicitly
        bounded ``max_drain_rounds``; always 0 in ``routing="host"`` mode),
        ``router_drain_rounds`` counts extra on-device exchange rounds
        beyond the first (key-skew indicator), ``router_syncs`` counts
        per-chunk watermark fetches (0 when ``sync_free``), and
        ``router_host_dict_ops`` counts label-map mutations inside
        dispatch (0 on the hash-routed path).  One device transfer
        (counters only) — this is a sync point."""
        import jax
        self._flush_dispatch()
        self._fold_labels()
        s = self.state
        phi, ne, tr, ac, sk, dr, drr = jax.device_get(
            (s.phi, s.num_edges, s.n_trials, s.n_accept, s.n_skipped,
             self.intern.n_dropped, self._drain_rounds))
        self._raise_if_dropped(int(np.sum(dr)))
        tot = lambda x: int(np.sum(x))  # noqa: E731
        return dict(phi=tot(phi), num_edges=tot(ne),
                    trials=tot(tr), accepted=tot(ac),
                    skipped=tot(sk), n_shards=self.n_shards,
                    routing=self.routing,
                    router_overflows=self.router_overflows,
                    # engine-stage carried telemetry: every device carries
                    # the same accumulated count (the drain loop is
                    # pmin-agreed), so max == the per-run total
                    router_drain_rounds=int(np.max(drr)),
                    router_syncs=self.router_syncs,
                    router_host_dict_ops=self._host_dict_ops,
                    router_sync_free=self.sync_free,
                    router_pipelined=self.pipeline,
                    # recoveries performed by a retry driver on this live
                    # object; deliberately NOT part of the checkpoint
                    # closure or the bitwise-recovery bar (it counts the
                    # recoveries themselves)
                    stream_retries=self.stream_retries)

    # ----------------------------------------------------- recovery closure
    def _ckpt_tree(self) -> dict:
        return {"est": self.state._asdict(), "ist": self.intern._asdict()}

    def _ckpt_host(self) -> dict:
        # host_label_map() is the sync point: drains the pipeline and folds
        # the lazy label buffer, so the map alone carries label recovery
        return {"h2label": dict(self.host_label_map()),
                "drain_rounds": np.asarray(self._drain_rounds),
                "router_overflows": self.router_overflows,
                "router_syncs": self.router_syncs,
                "host_dict_ops": self._host_dict_ops}

    def _ckpt_manifest(self) -> dict:
        # drain geometry only shapes the PRNG schedule when delivery is NOT
        # statically guaranteed (host-fallback replays shift it); pin the
        # exact geometry only in that regime so the default config stays
        # freely restorable across meshes (lane_cap derives from n_dev)
        guaranteed = bool(self.router_geometry.drain_guaranteed) \
            if self.router_geometry is not None else True
        return {"tier": "sharded", "config": self.cfg.manifest(),
                "n_shards": self.n_shards,
                "router_chunk": self.router_chunk,
                "drain_geometry": (None if guaranteed else
                                   [self.lane_cap, self.max_drain_rounds]),
                "routing": self.routing,
                "replica_exec": self.replica_exec,
                "trial_backend": self.trial_backend,
                "n_devices": int(self.mesh.devices.size)}

    @staticmethod
    def _ckpt_pins() -> tuple:
        # routing / replica_exec / trial_backend / n_devices are
        # bitwise-identical execution variants (standing differential bar)
        # — recorded, not pinned; config, shard placement, chunk boundaries
        # and an unguaranteed drain geometry all shape the replayed bits
        return ("tier", "config", "n_shards", "router_chunk",
                "drain_geometry")

    def _ckpt_apply(self, tree: dict, host: dict, extra: dict) -> None:
        from repro.dist import router as dist_router
        self.state = EngineState(**tree["est"])
        self.intern = dist_router.InternState(**tree["ist"])
        self._drain_rounds = dist_router.drain_telemetry_restore(
            host["drain_rounds"], int(self.mesh.devices.size))
        self._h2label = dict(host["h2label"])
        self._label_buf = []
        self._label_head = None
        self.router_overflows = int(host["router_overflows"])
        self.router_syncs = int(host["router_syncs"])
        self._host_dict_ops = int(host["host_dict_ops"])
        self._pending = None
        self._host_cache = None
        self._epoch = int(extra["epoch"])
        self._journal_seq = int(extra["journal_seq"])
        self._cursor = int(extra["cursor"])
        self._recovered = True
        self._incarnation += 1

    # ------------------------------------------------------------ materialize
    def live_edges(self) -> Set[Tuple[object, object]]:
        """Union of per-shard live edges, mapped back to caller labels."""
        out: Set[Tuple[object, object]] = set()
        for s, st in enumerate(self.host_states()):
            rev = self._shard_rev(s)
            for (a, b) in state_live_edges(st):
                out.add(pair_key(rev[a], rev[b]))
        return out

    def materialize(self) -> ShardedSummaryOutput:
        """Merged host-side output: per-shard lossless summaries in caller
        label space, supernode ids offset into disjoint per-shard ranges
        (``shard * n_cap``).  The relabeling reads the device intern maps,
        so it is exact under router-batched delivery: whatever order the
        all_to_all delivered changes in, ``l2h`` records the resulting nid
        assignment."""
        shards = []
        for s, st in enumerate(self.host_states()):
            out = state_materialize(st, self.cfg)
            shards.append(
                _relabel_output(out, self._shard_rev(s), s * self.cfg.n_cap))
        return ShardedSummaryOutput(shards=shards)

    def phi_recomputed(self) -> int:
        return sum(state_phi_recomputed(st, self.cfg)
                   for st in self.host_states())
