"""Host-side wrapper around the batched engine (Tier B public API)."""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterable, List, Sequence, Set, Tuple

import numpy as np

from repro.core.engine.state import EngineConfig, EngineState, new_state
from repro.core.engine.trial import make_step
from repro.core.summary import SummaryOutput, encoding_cost, is_superedge, pair_key

Change = Tuple[int, int, bool]


class BatchedSummarizer:
    """Feed a fully dynamic graph stream through the jitted engine step.

    Node ids are remapped into the engine's dense [0, n_cap) id space so
    callers may use arbitrary hashable node labels.
    """

    def __init__(self, cfg: EngineConfig | None = None, **overrides) -> None:
        if cfg is None:
            cfg = EngineConfig(**overrides)
        elif overrides:
            cfg = dataclasses.replace(cfg, **overrides)
        self.cfg = cfg
        self.state: EngineState = new_state(cfg)
        self._step = make_step(cfg)
        self._ids: Dict[object, int] = {}
        self._rev: List[object] = []

    # ------------------------------------------------------------------ ids
    def _nid(self, label: object) -> int:
        i = self._ids.get(label)
        if i is None:
            i = len(self._rev)
            assert i < self.cfg.n_cap, "node capacity exceeded"
            self._ids[label] = i
            self._rev.append(label)
        return i

    # --------------------------------------------------------------- stream
    def process(self, changes: Sequence[Change]) -> None:
        b = self.cfg.batch
        buf = [(self._nid(u), self._nid(v), ins) for (u, v, ins) in changes]
        for off in range(0, len(buf), b):
            chunk = buf[off:off + b]
            pad = b - len(chunk)
            u = np.array([c[0] for c in chunk] + [-1] * pad, np.int32)
            v = np.array([c[1] for c in chunk] + [-1] * pad, np.int32)
            ins = np.array([c[2] for c in chunk] + [False] * pad, bool)
            self.state = self._step(self.state, u, v, ins)

    def run(self, stream: Iterable[Change]) -> "BatchedSummarizer":
        self.process(list(stream))
        return self

    # ------------------------------------------------------------ maintenance
    def table_pressure(self) -> Dict[str, float]:
        """live+tombstone slot fraction per table (probe-chain health)."""
        from repro.core.engine.hashtable import TOMB
        out = {}
        for name in ("adj", "epos", "eab", "snadj", "snpos"):
            t = getattr(self.state, name)
            k1 = np.asarray(t.k1)
            out[name] = float(((k1 >= 0) | (k1 == int(TOMB))).mean())
        return out

    def maybe_compact(self, threshold: float = 0.7) -> bool:
        """Rebuild tables whose occupied fraction (live + tombstones) crosses
        ``threshold``.  Long fully-dynamic streams accumulate tombstones that
        stretch linear-probe chains; production deployments call this between
        steps (it is pure state -> state, so it composes with checkpoints).
        """
        from repro.core.engine.hashtable import ht_rebuild
        pressure = self.table_pressure()
        dirty = {n: p for n, p in pressure.items() if p > threshold}
        if not dirty:
            return False
        self.state = self.state._replace(
            **{n: ht_rebuild(getattr(self.state, n)) for n in dirty})
        return True

    # ---------------------------------------------------------------- stats
    @property
    def phi(self) -> int:
        return int(self.state.phi)

    @property
    def num_edges(self) -> int:
        return int(self.state.num_edges)

    def compression_ratio(self) -> float:
        e = self.num_edges
        return float(self.phi) / e if e else 0.0

    def stats(self) -> dict:
        s = self.state
        return dict(phi=int(s.phi), num_edges=int(s.num_edges),
                    trials=int(s.n_trials), accepted=int(s.n_accept),
                    skipped=int(s.n_skipped))

    # ------------------------------------------------------------ materialize
    def live_edges(self) -> Set[Tuple[int, int]]:
        """Export the live edge set from the slot-position table."""
        k1 = np.asarray(self.state.epos.k1)
        k2 = np.asarray(self.state.epos.k2)
        live = k1 >= 0
        return {(int(a), int(b)) for a, b in zip(k1[live], k2[live]) if a < b}

    def materialize(self) -> SummaryOutput:
        """Derive (G*, P, C+, C-) from counts + membership (optimal encoding)."""
        n2s = np.asarray(self.state.n2s)
        ssize = np.asarray(self.state.ssize)
        seen = n2s >= 0
        members: Dict[int, Set[int]] = {}
        for u in np.nonzero(seen)[0]:
            members.setdefault(int(n2s[u]), set()).add(int(u))
        for sid, mem in members.items():
            assert len(mem) == ssize[sid], f"ssize drift at sid {sid}"

        k1 = np.asarray(self.state.eab.k1)
        k2 = np.asarray(self.state.eab.k2)
        val = np.asarray(self.state.eab.val)
        live = k1 >= 0
        edges = self.live_edges()

        superedges: Set[Tuple[int, int]] = set()
        c_plus: Set[Tuple[int, int]] = set()
        c_minus: Set[Tuple[int, int]] = set()
        for a, b, e in zip(k1[live], k2[live], val[live]):
            a, b, e = int(a), int(b), int(e)
            sa, sb = len(members[a]), len(members[b])
            t = sa * (sa - 1) // 2 if a == b else sa * sb
            pair_edges = [pq for pq in _pairs(members[a], members[b], a == b)]
            actual = [pq for pq in pair_edges if pq in edges]
            assert len(actual) == e, f"eab drift at pair {(a, b)}: {len(actual)} != {e}"
            if is_superedge(e, t):
                superedges.add(pair_key(a, b))
                c_minus.update(pq for pq in pair_edges if pq not in edges)
            else:
                c_plus.update(actual)
        return SummaryOutput(supernodes=members, superedges=superedges,
                             c_plus=c_plus, c_minus=c_minus)

    def phi_recomputed(self) -> int:
        k1 = np.asarray(self.state.eab.k1)
        k2 = np.asarray(self.state.eab.k2)
        val = np.asarray(self.state.eab.val)
        ssize = np.asarray(self.state.ssize)
        live = k1 >= 0
        tot = 0
        for a, b, e in zip(k1[live], k2[live], val[live]):
            a, b = int(a), int(b)
            sa, sb = int(ssize[a]), int(ssize[b])
            t = sa * (sa - 1) // 2 if a == b else sa * sb
            tot += encoding_cost(int(e), t)
        return tot


def _pairs(ma: Set[int], mb: Set[int], same: bool):
    if same:
        mem = sorted(ma)
        for i, u in enumerate(mem):
            for v in mem[i + 1:]:
                yield (u, v)
    else:
        for u in sorted(ma):
            for v in sorted(mb):
                yield (u, v) if u < v else (v, u)
