"""Host-side wrappers around the batched engine (Tier B public API).

Two front-ends share the jitted step:

* :class:`BatchedSummarizer` — one engine on one device.
* :class:`ShardedSummarizer` — an edge-partitioned fleet of engines laid out
  over a 1-D device mesh via ``shard_map`` (one ``EngineState`` replica per
  partition, several replicas per device when ``n_shards`` exceeds the device
  count), merged into a :class:`ShardedSummaryOutput` on the host.  This is
  how the MoSSo engine scales past a single device's ``n_cap``.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterable, List, Optional, Sequence, Set, Tuple

import numpy as np

from repro.core.engine.state import EngineConfig, EngineState, new_state
from repro.core.engine.trial import make_step, step_fn
from repro.core.summary import (ShardedSummaryOutput, SummaryOutput,
                                encoding_cost, is_superedge, pair_key)

Change = Tuple[int, int, bool]


# --------------------------------------------------------------------------- #
# state-level exports (shared by both front-ends; engine-id space)
# --------------------------------------------------------------------------- #


def state_live_edges(state: EngineState) -> Set[Tuple[int, int]]:
    """Export the live edge set from the slot-position table."""
    k1 = np.asarray(state.epos.k1)
    k2 = np.asarray(state.epos.k2)
    live = k1 >= 0
    return {(int(a), int(b)) for a, b in zip(k1[live], k2[live]) if a < b}


def state_materialize(state: EngineState) -> SummaryOutput:
    """Derive (G*, P, C+, C-) from counts + membership (optimal encoding)."""
    n2s = np.asarray(state.n2s)
    ssize = np.asarray(state.ssize)
    seen = n2s >= 0
    members: Dict[int, Set[int]] = {}
    for u in np.nonzero(seen)[0]:
        members.setdefault(int(n2s[u]), set()).add(int(u))
    for sid, mem in members.items():
        assert len(mem) == ssize[sid], f"ssize drift at sid {sid}"

    k1 = np.asarray(state.eab.k1)
    k2 = np.asarray(state.eab.k2)
    val = np.asarray(state.eab.val)
    live = k1 >= 0
    edges = state_live_edges(state)

    superedges: Set[Tuple[int, int]] = set()
    c_plus: Set[Tuple[int, int]] = set()
    c_minus: Set[Tuple[int, int]] = set()
    for a, b, e in zip(k1[live], k2[live], val[live]):
        a, b, e = int(a), int(b), int(e)
        sa, sb = len(members[a]), len(members[b])
        t = sa * (sa - 1) // 2 if a == b else sa * sb
        pair_edges = [pq for pq in _pairs(members[a], members[b], a == b)]
        actual = [pq for pq in pair_edges if pq in edges]
        assert len(actual) == e, f"eab drift at pair {(a, b)}: {len(actual)} != {e}"
        if is_superedge(e, t):
            superedges.add(pair_key(a, b))
            c_minus.update(pq for pq in pair_edges if pq not in edges)
        else:
            c_plus.update(actual)
    return SummaryOutput(supernodes=members, superedges=superedges,
                         c_plus=c_plus, c_minus=c_minus)


def state_phi_recomputed(state: EngineState) -> int:
    k1 = np.asarray(state.eab.k1)
    k2 = np.asarray(state.eab.k2)
    val = np.asarray(state.eab.val)
    ssize = np.asarray(state.ssize)
    live = k1 >= 0
    tot = 0
    for a, b, e in zip(k1[live], k2[live], val[live]):
        a, b = int(a), int(b)
        sa, sb = int(ssize[a]), int(ssize[b])
        t = sa * (sa - 1) // 2 if a == b else sa * sb
        tot += encoding_cost(int(e), t)
    return tot


def _pairs(ma: Set[int], mb: Set[int], same: bool):
    if same:
        mem = sorted(ma)
        for i, u in enumerate(mem):
            for v in mem[i + 1:]:
                yield (u, v)
    else:
        for u in sorted(ma):
            for v in sorted(mb):
                yield (u, v) if u < v else (v, u)


def _relabel_output(out: SummaryOutput, rev: Sequence[object],
                    sid_offset: int) -> SummaryOutput:
    """Map a shard's engine-id output back to caller labels, with supernode
    ids offset into a globally unique range."""
    return SummaryOutput(
        supernodes={sid_offset + sid: {rev[u] for u in mem}
                    for sid, mem in out.supernodes.items()},
        superedges={(sid_offset + a, sid_offset + b)
                    for (a, b) in out.superedges},
        c_plus={pair_key(rev[a], rev[b]) for (a, b) in out.c_plus},
        c_minus={pair_key(rev[a], rev[b]) for (a, b) in out.c_minus},
    )


# --------------------------------------------------------------------------- #
# single-engine front-end
# --------------------------------------------------------------------------- #


class BatchedSummarizer:
    """Feed a fully dynamic graph stream through the jitted engine step.

    Node ids are remapped into the engine's dense [0, n_cap) id space so
    callers may use arbitrary hashable node labels.
    """

    def __init__(self, cfg: EngineConfig | None = None, **overrides) -> None:
        if cfg is None:
            cfg = EngineConfig(**overrides)
        elif overrides:
            cfg = dataclasses.replace(cfg, **overrides)
        self.cfg = cfg
        self.state: EngineState = new_state(cfg)
        self._step = make_step(cfg)
        self._ids: Dict[object, int] = {}
        self._rev: List[object] = []

    # ------------------------------------------------------------------ ids
    def _nid(self, label: object) -> int:
        i = self._ids.get(label)
        if i is None:
            i = len(self._rev)
            assert i < self.cfg.n_cap, "node capacity exceeded"
            self._ids[label] = i
            self._rev.append(label)
        return i

    # --------------------------------------------------------------- stream
    def process(self, changes: Sequence[Change]) -> None:
        b = self.cfg.batch
        buf = [(self._nid(u), self._nid(v), ins) for (u, v, ins) in changes]
        for off in range(0, len(buf), b):
            chunk = buf[off:off + b]
            pad = b - len(chunk)
            u = np.array([c[0] for c in chunk] + [-1] * pad, np.int32)
            v = np.array([c[1] for c in chunk] + [-1] * pad, np.int32)
            ins = np.array([c[2] for c in chunk] + [False] * pad, bool)
            self.state = self._step(self.state, u, v, ins)

    def run(self, stream: Iterable[Change]) -> "BatchedSummarizer":
        self.process(list(stream))
        return self

    # ------------------------------------------------------------ maintenance
    def table_pressure(self) -> Dict[str, float]:
        """live+tombstone slot fraction per table (probe-chain health)."""
        from repro.core.engine.hashtable import TOMB
        out = {}
        for name in ("adj", "epos", "eab", "snadj", "snpos"):
            t = getattr(self.state, name)
            k1 = np.asarray(t.k1)
            out[name] = float(((k1 >= 0) | (k1 == int(TOMB))).mean())
        return out

    def maybe_compact(self, threshold: float = 0.7) -> bool:
        """Rebuild tables whose occupied fraction (live + tombstones) crosses
        ``threshold``.  Long fully-dynamic streams accumulate tombstones that
        stretch linear-probe chains; production deployments call this between
        steps (it is pure state -> state, so it composes with checkpoints).
        """
        from repro.core.engine.hashtable import ht_rebuild
        pressure = self.table_pressure()
        dirty = {n: p for n, p in pressure.items() if p > threshold}
        if not dirty:
            return False
        self.state = self.state._replace(
            **{n: ht_rebuild(getattr(self.state, n)) for n in dirty})
        return True

    # ---------------------------------------------------------------- stats
    @property
    def phi(self) -> int:
        return int(self.state.phi)

    @property
    def num_edges(self) -> int:
        return int(self.state.num_edges)

    def compression_ratio(self) -> float:
        e = self.num_edges
        return float(self.phi) / e if e else 0.0

    def stats(self) -> dict:
        s = self.state
        return dict(phi=int(s.phi), num_edges=int(s.num_edges),
                    trials=int(s.n_trials), accepted=int(s.n_accept),
                    skipped=int(s.n_skipped))

    # ------------------------------------------------------------ materialize
    def live_edges(self) -> Set[Tuple[int, int]]:
        return state_live_edges(self.state)

    def materialize(self) -> SummaryOutput:
        return state_materialize(self.state)

    def phi_recomputed(self) -> int:
        return state_phi_recomputed(self.state)


# --------------------------------------------------------------------------- #
# sharded front-end
# --------------------------------------------------------------------------- #


def _make_sharded_step(cfg: EngineConfig, mesh):
    """jit(shard_map) over a stacked [n_shards, ...] state tree.

    Each device owns ``n_shards / n_devices`` independent engine replicas;
    ``lax.map`` over the local leading axis keeps the engine's control flow
    (cond/fori) intact instead of paying vmap's both-branches cost.
    """
    import jax
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    axis = mesh.axis_names[0]
    state_sds = jax.eval_shape(lambda: new_state(cfg))
    st_specs = jax.tree.map(lambda _: P(axis), state_sds)

    def local(st, u, v, ins):
        return jax.lax.map(
            lambda a: step_fn(a[0], a[1], a[2], a[3], cfg), (st, u, v, ins))

    return jax.jit(shard_map(
        local, mesh=mesh,
        in_specs=(st_specs, P(axis), P(axis), P(axis)),
        out_specs=st_specs, check_rep=False))


class ShardedSummarizer:
    """Edge-partitioned summarization across mesh devices.

    Every stream change is routed to the shard owning its canonical pair
    (``min(gid(u), gid(v)) % n_shards``), so each engine replica sees a
    deterministic, disjoint edge partition and summarizes it losslessly on
    its own ``n_cap``-bounded id space.  Aggregate capacity therefore grows
    linearly with the shard count.  The merged output is the union-of-parts
    encoding (:class:`ShardedSummaryOutput`); ``phi`` is the sum of shard
    phis since per-pair encodings never span shards.

    Unlike :class:`BatchedSummarizer` (whose outputs stay in engine-id
    space), ``live_edges``/``materialize`` report CALLER labels, so labels
    must be mutually orderable (ints, strings, ...) for the canonical pair
    keys; streaming itself accepts any hashable label.
    """

    def __init__(self, cfg: EngineConfig | None = None, *,
                 mesh=None, n_shards: Optional[int] = None,
                 **overrides) -> None:
        import jax
        import jax.numpy as jnp

        if cfg is None:
            cfg = EngineConfig(**overrides)
        elif overrides:
            cfg = dataclasses.replace(cfg, **overrides)
        self.cfg = cfg
        if mesh is None:
            from repro.launch.mesh import make_engine_mesh
            mesh = make_engine_mesh()
        self.mesh = mesh
        n_dev = int(mesh.devices.size)
        self.n_shards = n_dev if n_shards is None else int(n_shards)
        if self.n_shards % n_dev != 0:
            raise ValueError(
                f"n_shards={self.n_shards} must be a multiple of the mesh "
                f"device count {n_dev}")
        self._step = _make_sharded_step(cfg, mesh)

        state1 = new_state(cfg)
        n = self.n_shards
        stacked = jax.tree.map(
            lambda l: jnp.broadcast_to(l[None], (n,) + l.shape), state1)
        # decorrelate the per-shard trial PRNG streams
        stacked = stacked._replace(
            step_no=jnp.uint32(cfg.seed)
            + jnp.arange(n, dtype=jnp.uint32) * jnp.uint32(2654435761))
        self.state = stacked

        self._ids: List[Dict[object, int]] = [dict() for _ in range(n)]
        self._rev: List[List[object]] = [[] for _ in range(n)]
        self._gids: Dict[object, int] = {}
        self._host_cache: Optional[List[EngineState]] = None

    # ------------------------------------------------------------------ ids
    def _gid(self, label: object) -> int:
        g = self._gids.get(label)
        if g is None:
            g = len(self._gids)
            self._gids[label] = g
        return g

    def shard_of(self, u: object, v: object) -> int:
        """Deterministic owner shard of edge {u, v} (stable across the run)."""
        return min(self._gid(u), self._gid(v)) % self.n_shards

    def _nid(self, shard: int, label: object) -> int:
        ids = self._ids[shard]
        i = ids.get(label)
        if i is None:
            i = len(self._rev[shard])
            assert i < self.cfg.n_cap, f"shard {shard} node capacity exceeded"
            ids[label] = i
            self._rev[shard].append(label)
        return i

    # --------------------------------------------------------------- stream
    def process(self, changes: Sequence[Change]) -> None:
        n, b = self.n_shards, self.cfg.batch
        buckets: List[List[Tuple[int, int, bool]]] = [[] for _ in range(n)]
        for (u, v, ins) in changes:
            s = self.shard_of(u, v)
            buckets[s].append((self._nid(s, u), self._nid(s, v), ins))
        rounds = (max((len(q) for q in buckets), default=0) + b - 1) // b
        for r in range(rounds):
            u = np.full((n, b), -1, np.int32)
            v = np.full((n, b), -1, np.int32)
            ins = np.zeros((n, b), bool)
            for s in range(n):
                for j, (a, c, f) in enumerate(buckets[s][r * b:(r + 1) * b]):
                    u[s, j], v[s, j], ins[s, j] = a, c, f
            self.state = self._step(self.state, u, v, ins)
        self._host_cache = None

    def run(self, stream: Iterable[Change]) -> "ShardedSummarizer":
        self.process(list(stream))
        return self

    # ---------------------------------------------------------------- stats
    def host_states(self) -> List[EngineState]:
        """All shard states as host arrays: one device transfer, memoized
        until the next ``process`` call mutates the device state."""
        if self._host_cache is None:
            import jax
            stacked = jax.device_get(self.state)
            self._host_cache = [jax.tree.map(lambda x: x[s], stacked)
                                for s in range(self.n_shards)]
        return self._host_cache

    def shard_state(self, shard: int) -> EngineState:
        return self.host_states()[shard]

    def shard_phis(self) -> List[int]:
        return [int(x) for x in np.asarray(self.state.phi)]

    @property
    def phi(self) -> int:
        return sum(self.shard_phis())

    @property
    def num_edges(self) -> int:
        return int(np.asarray(self.state.num_edges).sum())

    def compression_ratio(self) -> float:
        e = self.num_edges
        return float(self.phi) / e if e else 0.0

    def stats(self) -> dict:
        s = self.state
        tot = lambda x: int(np.asarray(x).sum())  # noqa: E731
        return dict(phi=self.phi, num_edges=tot(s.num_edges),
                    trials=tot(s.n_trials), accepted=tot(s.n_accept),
                    skipped=tot(s.n_skipped), n_shards=self.n_shards)

    # ------------------------------------------------------------ materialize
    def live_edges(self) -> Set[Tuple[object, object]]:
        """Union of per-shard live edges, mapped back to caller labels."""
        out: Set[Tuple[object, object]] = set()
        for s, st in enumerate(self.host_states()):
            rev = self._rev[s]
            for (a, b) in state_live_edges(st):
                out.add(pair_key(rev[a], rev[b]))
        return out

    def materialize(self) -> ShardedSummaryOutput:
        """Merged host-side output: per-shard lossless summaries in label
        space, supernode ids offset into disjoint per-shard ranges."""
        shards = []
        for s, st in enumerate(self.host_states()):
            out = state_materialize(st)
            shards.append(_relabel_output(out, self._rev[s], s * self.cfg.n_cap))
        return ShardedSummaryOutput(shards=shards)

    def phi_recomputed(self) -> int:
        return sum(state_phi_recomputed(st) for st in self.host_states())
