"""Host-side wrappers around the batched engine (Tier B public API).

Two front-ends share the jitted step:

* :class:`BatchedSummarizer` — one engine on one device.
* :class:`ShardedSummarizer` — an edge-partitioned fleet of engines laid out
  over a 1-D device mesh via ``shard_map`` (one ``EngineState`` replica per
  partition, several replicas per device when ``n_shards`` exceeds the device
  count), merged into a :class:`ShardedSummaryOutput` on the host.  This is
  how the MoSSo engine scales past a single device's ``n_cap``.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterable, List, Optional, Sequence, Set, Tuple

import numpy as np

from repro.core.engine.state import EngineConfig, EngineState, new_state
from repro.core.engine.trial import make_step
from repro.core.summary import (ShardedSummaryOutput, SummaryOutput,
                                encoding_cost, is_superedge, pair_key)

Change = Tuple[int, int, bool]


# --------------------------------------------------------------------------- #
# state-level exports (shared by both front-ends; engine-id space)
# --------------------------------------------------------------------------- #


def state_live_edges(state: EngineState) -> Set[Tuple[int, int]]:
    """Export the live edge set from the slot-position table."""
    k1 = np.asarray(state.epos.k1)
    k2 = np.asarray(state.epos.k2)
    live = k1 >= 0
    return {(int(a), int(b)) for a, b in zip(k1[live], k2[live]) if a < b}


def state_materialize(state: EngineState) -> SummaryOutput:
    """Derive (G*, P, C+, C-) from counts + membership (optimal encoding)."""
    n2s = np.asarray(state.n2s)
    ssize = np.asarray(state.ssize)
    seen = n2s >= 0
    members: Dict[int, Set[int]] = {}
    for u in np.nonzero(seen)[0]:
        members.setdefault(int(n2s[u]), set()).add(int(u))
    for sid, mem in members.items():
        assert len(mem) == ssize[sid], f"ssize drift at sid {sid}"

    k1 = np.asarray(state.eab.k1)
    k2 = np.asarray(state.eab.k2)
    val = np.asarray(state.eab.val)
    live = k1 >= 0
    edges = state_live_edges(state)

    superedges: Set[Tuple[int, int]] = set()
    c_plus: Set[Tuple[int, int]] = set()
    c_minus: Set[Tuple[int, int]] = set()
    for a, b, e in zip(k1[live], k2[live], val[live]):
        a, b, e = int(a), int(b), int(e)
        sa, sb = len(members[a]), len(members[b])
        t = sa * (sa - 1) // 2 if a == b else sa * sb
        pair_edges = [pq for pq in _pairs(members[a], members[b], a == b)]
        actual = [pq for pq in pair_edges if pq in edges]
        assert len(actual) == e, f"eab drift at pair {(a, b)}: {len(actual)} != {e}"
        if is_superedge(e, t):
            superedges.add(pair_key(a, b))
            c_minus.update(pq for pq in pair_edges if pq not in edges)
        else:
            c_plus.update(actual)
    return SummaryOutput(supernodes=members, superedges=superedges,
                         c_plus=c_plus, c_minus=c_minus)


def state_phi_recomputed(state: EngineState) -> int:
    k1 = np.asarray(state.eab.k1)
    k2 = np.asarray(state.eab.k2)
    val = np.asarray(state.eab.val)
    ssize = np.asarray(state.ssize)
    live = k1 >= 0
    tot = 0
    for a, b, e in zip(k1[live], k2[live], val[live]):
        a, b = int(a), int(b)
        sa, sb = int(ssize[a]), int(ssize[b])
        t = sa * (sa - 1) // 2 if a == b else sa * sb
        tot += encoding_cost(int(e), t)
    return tot


def _pairs(ma: Set[int], mb: Set[int], same: bool):
    if same:
        mem = sorted(ma)
        for i, u in enumerate(mem):
            for v in mem[i + 1:]:
                yield (u, v)
    else:
        for u in sorted(ma):
            for v in sorted(mb):
                yield (u, v) if u < v else (v, u)


def _relabel_output(out: SummaryOutput, rev: Sequence[object],
                    sid_offset: int) -> SummaryOutput:
    """Map a shard's engine-id output back to caller labels, with supernode
    ids offset into a globally unique range."""
    return SummaryOutput(
        supernodes={sid_offset + sid: {rev[u] for u in mem}
                    for sid, mem in out.supernodes.items()},
        superedges={(sid_offset + a, sid_offset + b)
                    for (a, b) in out.superedges},
        c_plus={pair_key(rev[a], rev[b]) for (a, b) in out.c_plus},
        c_minus={pair_key(rev[a], rev[b]) for (a, b) in out.c_minus},
    )


# --------------------------------------------------------------------------- #
# single-engine front-end
# --------------------------------------------------------------------------- #


class BatchedSummarizer:
    """Feed a fully dynamic graph stream through the jitted engine step.

    **Id space.** ``process``/``run`` accept arbitrary hashable caller
    labels and intern them (host-side, encounter order) into the engine's
    dense ``[0, n_cap)`` id space.  Outputs stay in ENGINE ids:
    ``live_edges``/``materialize``/``phi_recomputed`` report engine-id
    pairs; map engine ids back to labels through ``self._rev`` (or map a
    label-space ground truth into engine ids through ``self._ids``) when
    comparing — the sharded front-end, by contrast, reports caller labels.

    **Capacity.** One engine, one device: at most ``n_cap`` distinct
    labels ever seen (asserted at interning time) and ``m_cap`` live edges
    (a table-sizing contract, unchecked — see :class:`EngineConfig`).
    Scale past either with :class:`ShardedSummarizer`.
    """

    def __init__(self, cfg: EngineConfig | None = None, **overrides) -> None:
        if cfg is None:
            cfg = EngineConfig(**overrides)
        elif overrides:
            cfg = dataclasses.replace(cfg, **overrides)
        self.cfg = cfg
        self.state: EngineState = new_state(cfg)
        self._step = make_step(cfg)
        self._ids: Dict[object, int] = {}
        self._rev: List[object] = []

    # ------------------------------------------------------------------ ids
    def _nid(self, label: object) -> int:
        i = self._ids.get(label)
        if i is None:
            i = len(self._rev)
            assert i < self.cfg.n_cap, "node capacity exceeded"
            self._ids[label] = i
            self._rev.append(label)
        return i

    # --------------------------------------------------------------- stream
    def process(self, changes: Sequence[Change]) -> None:
        b = self.cfg.batch
        buf = [(self._nid(u), self._nid(v), ins) for (u, v, ins) in changes]
        for off in range(0, len(buf), b):
            chunk = buf[off:off + b]
            pad = b - len(chunk)
            u = np.array([c[0] for c in chunk] + [-1] * pad, np.int32)
            v = np.array([c[1] for c in chunk] + [-1] * pad, np.int32)
            ins = np.array([c[2] for c in chunk] + [False] * pad, bool)
            self.state = self._step(self.state, u, v, ins)

    def run(self, stream: Iterable[Change]) -> "BatchedSummarizer":
        self.process(list(stream))
        return self

    # ------------------------------------------------------------ maintenance
    def table_pressure(self) -> Dict[str, float]:
        """live+tombstone slot fraction per table (probe-chain health)."""
        from repro.core.engine.hashtable import TOMB
        out = {}
        for name in ("adj", "epos", "eab", "snadj", "snpos"):
            t = getattr(self.state, name)
            k1 = np.asarray(t.k1)
            out[name] = float(((k1 >= 0) | (k1 == int(TOMB))).mean())
        return out

    def maybe_compact(self, threshold: float = 0.7) -> bool:
        """Rebuild tables whose occupied fraction (live + tombstones) crosses
        ``threshold``.  Long fully-dynamic streams accumulate tombstones that
        stretch linear-probe chains; production deployments call this between
        steps (it is pure state -> state, so it composes with checkpoints).
        """
        from repro.core.engine.hashtable import ht_rebuild
        pressure = self.table_pressure()
        dirty = {n: p for n, p in pressure.items() if p > threshold}
        if not dirty:
            return False
        self.state = self.state._replace(
            **{n: ht_rebuild(getattr(self.state, n)) for n in dirty})
        return True

    # ---------------------------------------------------------------- stats
    @property
    def phi(self) -> int:
        return int(self.state.phi)

    @property
    def num_edges(self) -> int:
        return int(self.state.num_edges)

    def compression_ratio(self) -> float:
        e = self.num_edges
        return float(self.phi) / e if e else 0.0

    def stats(self) -> dict:
        s = self.state
        return dict(phi=int(s.phi), num_edges=int(s.num_edges),
                    trials=int(s.n_trials), accepted=int(s.n_accept),
                    skipped=int(s.n_skipped))

    # ------------------------------------------------------------ materialize
    def live_edges(self) -> Set[Tuple[int, int]]:
        return state_live_edges(self.state)

    def materialize(self) -> SummaryOutput:
        return state_materialize(self.state)

    def phi_recomputed(self) -> int:
        return state_phi_recomputed(self.state)


# --------------------------------------------------------------------------- #
# sharded front-end
# --------------------------------------------------------------------------- #


class ShardedSummarizer:
    """Edge-partitioned summarization across mesh devices.

    Every stream change is routed to the shard owning its canonical pair
    (``min(gid(u), gid(v)) % n_shards``), so each engine replica sees a
    deterministic, disjoint edge partition and summarizes it losslessly on
    its own ``n_cap``-bounded id space.  Aggregate capacity therefore grows
    linearly with the shard count.  The merged output is the union-of-parts
    encoding (:class:`ShardedSummaryOutput`); ``phi`` is the sum of shard
    phis since per-pair encodings never span shards.

    **Id spaces.** Three layers, all host-recoverable:

    * caller labels — any hashable (streaming) / mutually orderable
      (``live_edges``/``materialize``) values;
    * gids — dense ints assigned by the host in label-encounter order
      (``_gid``); the routing key is computed on gids;
    * per-shard local nids — dense ``[0, n_cap)`` ids the engine state is
      indexed by, assigned ON DEVICE in delivery order by the intern tables
      of :mod:`repro.dist.router` (both routing modes assign identically).

    **Routing modes** (``routing=``):

    * ``"device"`` (default) — changes stream through the jit-compiled
      router: shard keys, a capacity-bounded ``all_to_all`` exchange (run
      as a bounded on-device drain loop when a (source, shard) lane
      exceeds ``lane_cap``), and the engine rounds all run in one fused
      device program per chunk of ``router_chunk`` changes.  With the
      default ``max_drain_rounds`` delivery of a full chunk is statically
      guaranteed, so dispatch is **sync-free**: no per-chunk host fetch,
      and the host stages chunk k+1 while chunk k computes.  Only an
      explicitly lowered ``max_drain_rounds`` (or ``chunk_sync=True``)
      reinstates the per-chunk watermark fetch; a suffix left undelivered
      when the round budget runs out falls back to the host path below and
      ``router_overflows`` counts the spilled changes.
    * ``"host"`` — the differential reference: the host buckets gids per
      shard and feeds padded ``[n_shards, batch]`` rounds.  Given identical
      ``process`` call boundaries (calls no longer than ``router_chunk``),
      both modes produce bit-identical engine states — including through
      multi-round drains — as long as no host fallback ran (the fallback
      legitimately shifts the PRNG schedule).

    **Routing telemetry.** ``router_syncs`` counts per-chunk watermark
    fetches (0 when ``sync_free``), ``router_overflows`` counts changes
    replayed through the host path, and ``stats()['router_drain_rounds']``
    counts extra drain rounds beyond the first (device-resident counter,
    fetched only at sync points).

    **Capacity semantics.** Edge partitioning is a vertex cut: a node
    touching edges in several partitions occupies a local id in each, so
    per-shard ``n_cap`` must budget the replication factor (see
    ``src/repro/dist/README.md``).  The host path and the device path both
    intern on device; exceeding ``n_cap`` increments a per-shard
    ``n_dropped`` counter and skips the change, and the next host-side
    sync point (``phi``/``stats``/``materialize``/...) raises
    ``RuntimeError`` — a dropped change would otherwise silently break
    losslessness.
    """

    def __init__(self, cfg: EngineConfig | None = None, *,
                 mesh=None, n_shards: Optional[int] = None,
                 routing: str = "device", router_chunk: int = 1024,
                 lane_cap: Optional[int] = None,
                 max_drain_rounds: Optional[int] = None,
                 chunk_sync: bool = False,
                 **overrides) -> None:
        import math

        import jax
        import jax.numpy as jnp

        from repro.dist import router as dist_router

        if cfg is None:
            cfg = EngineConfig(**overrides)
        elif overrides:
            cfg = dataclasses.replace(cfg, **overrides)
        self.cfg = cfg
        if mesh is None:
            from repro.launch.mesh import make_engine_mesh
            if n_shards is None:
                mesh = make_engine_mesh()
            else:
                # fit the mesh to the shard count: n_shards replicas spread
                # over the largest local device subset that divides them
                mesh = make_engine_mesh(
                    math.gcd(int(n_shards), len(jax.devices())))
        self.mesh = mesh
        n_dev = int(mesh.devices.size)
        self.n_shards = n_dev if n_shards is None else int(n_shards)
        if self.n_shards % n_dev != 0:
            raise ValueError(
                f"n_shards={self.n_shards} must be a multiple of the mesh "
                f"device count {n_dev}")
        if routing not in ("device", "host"):
            raise ValueError(f"routing must be 'device' or 'host': {routing}")
        self.routing = routing
        # round the chunk up so it splits evenly over the devices
        self.router_chunk = -(-int(router_chunk) // n_dev) * n_dev
        self.lane_cap = (dist_router.default_lane_cap(
            self.router_chunk, n_dev, self.n_shards, cfg.batch)
            if lane_cap is None
            else min(int(lane_cap), self.router_chunk // n_dev))
        self.router_overflows = 0   # changes spilled to the host path
        self.router_syncs = 0       # per-chunk watermark fetches performed
        self.chunk_sync = bool(chunk_sync)
        self._drain_rounds = 0      # folded drain counter (device scalar)
        self._drain_parts: List = []  # unfolded per-chunk round counts
        self._bucketed = dist_router.make_bucketed_step(cfg, mesh)
        if routing == "device":
            self._routed, self.router_geometry = dist_router.make_routed_step(
                cfg, mesh, self.n_shards, self.router_chunk, self.lane_cap,
                max_drain_rounds)
            self.lane_cap = self.router_geometry.lane_cap
            self.max_drain_rounds = self.router_geometry.max_drain_rounds
            # delivery statically guaranteed -> the overflow watermark never
            # gates anything and dispatch needs no per-chunk host round-trip
            self.sync_free = (self.router_geometry.drain_guaranteed
                              and not self.chunk_sync)
        else:
            self._routed, self.router_geometry = None, None
            self.max_drain_rounds = None
            self.sync_free = False

        state1 = new_state(cfg)
        n = self.n_shards
        stacked = jax.tree.map(
            lambda l: jnp.broadcast_to(l[None], (n,) + l.shape), state1)
        # decorrelate the per-shard trial PRNG streams
        stacked = stacked._replace(
            step_no=jnp.uint32(cfg.seed)
            + jnp.arange(n, dtype=jnp.uint32) * jnp.uint32(2654435761))
        self.state = stacked
        ist1 = dist_router.intern_new(cfg)
        self.intern = jax.tree.map(
            lambda l: jnp.broadcast_to(l[None], (n,) + l.shape), ist1)

        self._gids: Dict[object, int] = {}
        self._labels: List[object] = []     # gid -> caller label
        self._host_cache = None

    # ------------------------------------------------------------------ ids
    def _gid(self, label: object) -> int:
        g = self._gids.get(label)
        if g is None:
            g = len(self._gids)
            self._gids[label] = g
            self._labels.append(label)
        return g

    def shard_of(self, u: object, v: object) -> int:
        """Deterministic owner shard of a STREAMED edge {u, v}.

        Read-only: raises ``LookupError`` for labels this summarizer has
        not seen yet.  (Assigning gids here would silently shift every
        later label's routing — and desynchronize a differential pair of
        runs — just by *querying* placement.)
        """
        try:
            gu, gv = self._gids[u], self._gids[v]
        except KeyError as e:
            raise LookupError(
                f"shard_of: label {e.args[0]!r} has not been streamed; "
                f"gids (and therefore placement) are assigned in stream "
                f"encounter order") from None
        return min(gu, gv) % self.n_shards

    # --------------------------------------------------------------- stream
    def process(self, changes: Sequence[Change]) -> None:
        """Apply a sequence of changes, ``router_chunk`` at a time.

        Both routing modes consume the same chunk boundaries, so a host- and
        a device-routed run fed identical calls stay comparable change for
        change.
        """
        changes = list(changes)
        for off in range(0, len(changes), self.router_chunk):
            chunk = changes[off:off + self.router_chunk]
            if self.routing == "device":
                self._process_chunk_device(chunk)
            else:
                self._process_chunk_host(chunk)

    def _process_chunk_host(self, chunk: Sequence[Change]) -> None:
        """Host routing: bucket gids per shard, feed padded rounds."""
        n, b = self.n_shards, self.cfg.batch
        buckets: List[List[Tuple[int, int, bool]]] = [[] for _ in range(n)]
        for (u, v, ins) in chunk:
            gu, gv = self._gid(u), self._gid(v)
            buckets[min(gu, gv) % n].append((gu, gv, ins))
        rounds = (max((len(q) for q in buckets), default=0) + b - 1) // b
        for r in range(rounds):
            gu = np.full((n, b), -1, np.int32)
            gv = np.full((n, b), -1, np.int32)
            fl = np.zeros((n, b), np.int32)
            for s in range(n):
                for j, (a, c, f) in enumerate(buckets[s][r * b:(r + 1) * b]):
                    gu[s, j], gv[s, j], fl[s, j] = a, c, f
            self.state, self.intern = self._bucketed(
                self.state, self.intern, gu, gv, fl)
        self._host_cache = None

    def _process_chunk_device(self, chunk: Sequence[Change]) -> None:
        """Device routing: one fused router dispatch per chunk; lane
        overflow drains through additional on-device exchange rounds.

        In the default (``sync_free``) configuration this method performs
        ZERO device-to-host transfers: the dispatch returns immediately
        (jax async dispatch) and the host stages the next chunk while this
        one computes — the drain-round telemetry accumulates as a lazy
        device scalar fetched only at sync points.  Only when the drain
        budget is explicitly bounded (``max_drain_rounds`` below the
        delivery guarantee) or ``chunk_sync=True`` does the watermark get
        fetched per chunk, gating the host-path replay of an undelivered
        suffix so stream order — and therefore losslessness — is
        preserved."""
        c = self.router_chunk
        gu = np.full((c,), -1, np.int32)
        gv = np.full((c,), -1, np.int32)
        fl = np.zeros((c,), np.int32)
        for i, (u, v, ins) in enumerate(chunk):
            gu[i], gv[i], fl[i] = self._gid(u), self._gid(v), ins
        self.state, self.intern, delivered, rounds = self._routed(
            self.state, self.intern, gu, gv, fl)
        self._host_cache = None
        # drain telemetry: a list append per chunk (no device dispatch on
        # the sync-free hot path); folded device-side every 64 chunks
        self._drain_parts.append(rounds)
        if len(self._drain_parts) >= 64:
            self._fold_drain_rounds()
        if self.sync_free:
            return                           # statically fully delivered
        self.router_syncs += 1
        i0 = int(np.asarray(delivered).min())  # per-chunk sync (fallback gate)
        if i0 < len(chunk):
            self.router_overflows += len(chunk) - i0
            self._process_chunk_host(chunk[i0:])

    def _fold_drain_rounds(self) -> None:
        """Fold the buffered per-chunk drain-round counts into the running
        device scalar.  Device-side only — never fetches — so calling it
        from the dispatch path preserves the sync-free contract."""
        if not self._drain_parts:
            return
        import jax.numpy as jnp
        stack = jnp.stack(self._drain_parts)   # [chunks, n_dev]
        self._drain_rounds = (self._drain_rounds
                              + jnp.sum(jnp.max(stack, axis=1) - 1))
        self._drain_parts.clear()

    def run(self, stream: Iterable[Change]) -> "ShardedSummarizer":
        self.process(list(stream))
        return self

    # ---------------------------------------------------------------- stats
    def host_states(self) -> List[EngineState]:
        """All shard engine states as host arrays: one device transfer,
        memoized until the next ``process`` call mutates the device state.
        Engine states index nodes by per-shard local nid."""
        return self._host_fetch()[0]

    def host_interns(self) -> List["object"]:
        """Per-shard intern states (gid <-> local nid maps) on the host."""
        return self._host_fetch()[1]

    def _host_fetch(self):
        if self._host_cache is None:
            import jax
            est, ist = jax.device_get((self.state, self.intern))
            self._host_cache = (
                [jax.tree.map(lambda x: x[s], est)
                 for s in range(self.n_shards)],
                [jax.tree.map(lambda x: x[s], ist)
                 for s in range(self.n_shards)])
        self._check_capacity()
        return self._host_cache

    def _check_capacity(self) -> None:
        if self._host_cache is not None:   # free: counters already fetched
            dropped = sum(int(i.n_dropped) for i in self._host_cache[1])
        else:
            dropped = int(np.asarray(self.intern.n_dropped).sum())
        self._raise_if_dropped(dropped)

    def _raise_if_dropped(self, dropped: int) -> None:
        if dropped:
            raise RuntimeError(
                f"node capacity exceeded: {dropped} endpoint interns dropped "
                f"(per-shard n_cap={self.cfg.n_cap}; raise n_cap or n_shards "
                f"— losslessness does not hold for the dropped changes)")

    def _shard_rev(self, shard: int) -> List[object]:
        """nid -> caller label for one shard, from the device intern map."""
        ist = self.host_interns()[shard]
        n = int(ist.n_nodes)
        return [self._labels[int(g)] for g in np.asarray(ist.l2g)[:n]]

    def shard_state(self, shard: int) -> EngineState:
        return self.host_states()[shard]

    def shard_phis(self) -> List[int]:
        self._check_capacity()
        return [int(x) for x in np.asarray(self.state.phi)]

    @property
    def phi(self) -> int:
        """Global objective: sum of shard phis (per-pair encodings never
        span shards, so the union-of-parts cost is exactly additive)."""
        return sum(self.shard_phis())

    @property
    def num_edges(self) -> int:
        self._check_capacity()
        return int(np.asarray(self.state.num_edges).sum())

    def compression_ratio(self) -> float:
        e = self.num_edges
        return float(self.phi) / e if e else 0.0

    def stats(self) -> dict:
        """Aggregate engine counters plus routing telemetry:
        ``router_overflows`` counts changes that spilled from the device
        router back to the host path (only possible with an explicitly
        bounded ``max_drain_rounds``; always 0 in ``routing="host"`` mode),
        ``router_drain_rounds`` counts extra on-device exchange rounds
        beyond the first (key-skew indicator), and ``router_syncs`` counts
        per-chunk watermark fetches (0 when ``sync_free``).  One device
        transfer (counters only) — this is a sync point."""
        import jax
        self._fold_drain_rounds()
        s = self.state
        phi, ne, tr, ac, sk, dr, drr = jax.device_get(
            (s.phi, s.num_edges, s.n_trials, s.n_accept, s.n_skipped,
             self.intern.n_dropped, self._drain_rounds))
        self._raise_if_dropped(int(np.sum(dr)))
        tot = lambda x: int(np.sum(x))  # noqa: E731
        return dict(phi=tot(phi), num_edges=tot(ne),
                    trials=tot(tr), accepted=tot(ac),
                    skipped=tot(sk), n_shards=self.n_shards,
                    routing=self.routing,
                    router_overflows=self.router_overflows,
                    router_drain_rounds=tot(drr),
                    router_syncs=self.router_syncs,
                    router_sync_free=self.sync_free)

    # ------------------------------------------------------------ materialize
    def live_edges(self) -> Set[Tuple[object, object]]:
        """Union of per-shard live edges, mapped back to caller labels."""
        out: Set[Tuple[object, object]] = set()
        for s, st in enumerate(self.host_states()):
            rev = self._shard_rev(s)
            for (a, b) in state_live_edges(st):
                out.add(pair_key(rev[a], rev[b]))
        return out

    def materialize(self) -> ShardedSummaryOutput:
        """Merged host-side output: per-shard lossless summaries in caller
        label space, supernode ids offset into disjoint per-shard ranges
        (``shard * n_cap``).  The relabeling reads the device intern maps,
        so it is exact under router-batched delivery: whatever order the
        all_to_all delivered changes in, ``l2g`` records the resulting nid
        assignment."""
        shards = []
        for s, st in enumerate(self.host_states()):
            out = state_materialize(st)
            shards.append(
                _relabel_output(out, self._shard_rev(s), s * self.cfg.n_cap))
        return ShardedSummaryOutput(shards=shards)

    def phi_recomputed(self) -> int:
        return sum(state_phi_recomputed(st) for st in self.host_states())
