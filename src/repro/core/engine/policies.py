"""Swappable Alg.-1 policies: proposal / objective / commit rule.

The trial engine (:mod:`repro.core.engine.trial`) is a fixed predicated
skeleton — sample TP, plan a candidate move, score it, commit — and this
module supplies the three plug points as plain Python registries keyed by
the static ``EngineConfig`` fields:

* ``PROPOSALS[cfg.proposal]`` — candidate destination generation:
  ``(st, y, tp, tp_minh, seed, cfg) -> (cand_target, cand_ok)``.
* ``OBJECTIVES[cfg.objective]`` — move scoring:
  ``(st, y, target, is_fresh, cfg) -> (dphi, nbrs, nvalid)``.
* ``COMMIT_RULES[cfg.commit]`` — accept rule: ``(dphi, cfg) -> bool``.

Dispatch is resolved at TRACE time (the config fields are static and part
of every compile-cache key), so a compiled step contains exactly one
policy triple and zero ``lax.cond`` — the cond-free tripwire in
``tests/test_differential.py`` runs over the whole registry matrix.
Every policy body must follow the ops-layer predication contract: pure
masked data flow, reads allowed on garbage lanes, commits gated by the
caller's predicates.

The canonical name tuples live in ``state.py`` (this module imports the
state module, not vice versa); ``tests/test_policies.py`` pins the
registry keys to them.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.core.engine.ops import (delta_phi_move, delta_phi_move_weighted,
                                   rnd_below)
from repro.core.engine.state import (COMMIT_RULES as COMMIT_RULE_NAMES,
                                     NO_CLUSTER, EngineConfig, EngineState)
from repro.core.engine.state import OBJECTIVES as OBJECTIVE_NAMES
from repro.core.engine.state import PROPOSALS as PROPOSAL_NAMES


def propose_minhash(st: EngineState, y: jax.Array, tp: jax.Array,
                    tp_minh: jax.Array, seed: jax.Array, cfg: EngineConfig,
                    ) -> Tuple[jax.Array, jax.Array]:
    """The paper's sampler: CP(y) = TP(u) ∩ R(y) via min-hash cluster
    equality, uniform pick among the matches (Alg. 1 step 4)."""
    a = st.n2s[y]
    my = st.minh[y]
    cp_mask = (tp_minh == my) & (my != NO_CLUSTER)
    n_cp = jnp.sum(cp_mask).astype(jnp.int32)
    pick = rnd_below(seed, jnp.uint32(4), n_cp)
    # index of the pick-th True in cp_mask
    csum = jnp.cumsum(cp_mask.astype(jnp.int32)) - 1
    zidx = jnp.argmax((csum == pick) & cp_mask)
    z = tp[zidx]
    cand_target = st.n2s[z]
    return cand_target, (n_cp > 0) & (cand_target != a)


def propose_magsdm(st: EngineState, y: jax.Array, tp: jax.Array,
                   tp_minh: jax.Array, seed: jax.Array, cfg: EngineConfig,
                   ) -> Tuple[jax.Array, jax.Array]:
    """Mags-DM-style dense-neighborhood grouping: the MODAL supernode
    among the TP samples (most co-sampled destination), not a uniform
    pick from a min-hash cluster.

    Deterministic given the samples — the randomness lives entirely in TP
    and the escape draw.  Fixed-shape analog of Mags-DM's grouping stage;
    the deviation vs the published heuristic is audited in
    ``docs/KNOWN_ISSUES.md``.
    """
    a = st.n2s[y]
    nsid = st.n2s[tp]
    cnt = (nsid[None, :] == nsid[:, None]).sum(axis=1).astype(jnp.int32)
    elig = nsid != a
    score = jnp.where(elig, cnt, -1)
    cand_target = nsid[jnp.argmax(score)]
    return cand_target, (jnp.sum(elig) > 0) & (cand_target != a)


def commit_saving(dphi: jax.Array, cfg: EngineConfig) -> jax.Array:
    """Move-if-saved (the paper's rule): accept iff dphi <= 0."""
    return dphi <= 0


def commit_threshold(dphi: jax.Array, cfg: EngineConfig) -> jax.Array:
    """Accept iff dphi <= cfg.commit_margin.

    margin > 0 tolerates small regressions (annealing-style exploration);
    margin < 0 demands strict improvement.  ``commit_margin=0`` is
    exactly ``saving``.
    """
    return dphi <= jnp.int32(cfg.commit_margin)


PROPOSALS = {
    "minhash": propose_minhash,
    "magsdm": propose_magsdm,
}

OBJECTIVES = {
    "exact": delta_phi_move,
    "weighted": delta_phi_move_weighted,
}

COMMIT_RULES = {
    "saving": commit_saving,
    "threshold": commit_threshold,
}

assert tuple(PROPOSALS) == PROPOSAL_NAMES
assert tuple(OBJECTIVES) == OBJECTIVE_NAMES
assert tuple(COMMIT_RULES) == COMMIT_RULE_NAMES
