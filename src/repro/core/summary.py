"""Shared lossless-summary primitives.

The output representation of lossless graph summarization (Sect. 2.1 of the
paper) is a summary graph ``G* = (S, P)`` plus edge corrections
``C = (C+, C-)``.  The *optimal encoding* rule (Sect. 3.1) decides, per
supernode pair {A, B}, whether the ``E_AB`` edges are cheaper listed verbatim
in C+ (cost ``|E_AB|``) or as one superedge plus the missing pairs in C-
(cost ``1 + |T_AB| - |E_AB|``).

These closed forms are shared by the faithful reference implementation
(:mod:`repro.core.reference`) and the batched JAX engine
(:mod:`repro.core.engine`).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Set, Tuple

Pair = Tuple[int, int]


def pair_key(a: int, b: int) -> Pair:
    """Canonical (unordered) supernode pair key."""
    return (a, b) if a <= b else (b, a)


def t_count(size_a: int, size_b: int, same: bool) -> int:
    """|T_AB|: number of potential edges between supernodes of the given sizes."""
    if same:
        return size_a * (size_a - 1) // 2
    return size_a * size_b


def encoding_cost(e: int, t: int) -> int:
    """Contribution of one supernode pair to phi under the optimal encoding.

    C+ mode costs ``e``; superedge mode costs ``1 + t - e``.  The optimal rule
    (Sect. 3.1) picks superedge iff ``e > (t + 1) / 2`` which is exactly the
    argmin, so the cost is ``min(e, t - e + 1)`` (and 0 when no edge exists).
    """
    if e <= 0:
        return 0
    return min(e, t - e + 1)


def is_superedge(e: int, t: int) -> bool:
    """Optimal-encoding mode for a pair: superedge iff |E| > (|T|+1)/2."""
    return 2 * e > t + 1


@dataclass
class SummaryOutput:
    """A materialized output representation (used for tests / persistence)."""

    supernodes: Dict[int, Set[int]]             # sid -> member nodes
    superedges: Set[Pair]                       # P  (canonical sid pairs)
    c_plus: Set[Pair]                           # C+ (canonical node pairs)
    c_minus: Set[Pair]                          # C- (canonical node pairs)

    @property
    def phi(self) -> int:
        return len(self.superedges) + len(self.c_plus) + len(self.c_minus)

    def phi_weighted(self, node_weight) -> int:
        """Utility-weighted objective of this representation: a superedge
        still costs 1, but each correction costs its pair weight
        ``w(u) * w(v)``.  With ``node_weight = lambda u: 1`` this equals
        :attr:`phi`; it is what the weighted-objective engine/reference
        maintain as their ``phi``.
        """
        corr = sum(node_weight(u) * node_weight(v)
                   for s in (self.c_plus, self.c_minus) for (u, v) in s)
        return len(self.superedges) + corr

    def decode_edges(self) -> Set[Pair]:
        """Losslessly recover E = (Ê ∪ C+) \\ C-  (Sect. 2.1)."""
        node2sid = {}
        for sid, mem in self.supernodes.items():
            for u in mem:
                node2sid[u] = sid
        edges: Set[Pair] = set()
        members = {sid: sorted(mem) for sid, mem in self.supernodes.items()}
        for a, b in self.superedges:
            if a == b:
                mem = members[a]
                for i, u in enumerate(mem):
                    for v in mem[i + 1:]:
                        edges.add(pair_key(u, v))
            else:
                for u in members[a]:
                    for v in members[b]:
                        edges.add(pair_key(u, v))
        edges |= {pair_key(u, v) for (u, v) in self.c_plus}
        edges -= {pair_key(u, v) for (u, v) in self.c_minus}
        return edges

    def node_count(self) -> int:
        return sum(len(m) for m in self.supernodes.values())


@dataclass
class ShardedSummaryOutput:
    """Union-of-parts output of an edge-partitioned summarization run.

    Each shard is an independent lossless summary of its edge partition, so
    the global edge set is the UNION of per-shard decodes.  C- stays scoped
    to its shard: a node may belong to supernodes in several shards, and a
    shard's correction must never subtract an edge owned by another shard,
    which is why the parts are kept rather than flattened into one
    :class:`SummaryOutput`.

    The merge is insensitive to DELIVERY ORDER: whether changes reached a
    shard through host bucketing or through the device router's
    ``all_to_all`` batches (``repro/dist/router.py``), each edge has exactly
    one owner shard (canonical-pair keying), so the per-shard summaries
    cover disjoint edge sets and the union — and the additive ``phi`` — are
    the same.  What delivery order *does* fix is each shard's internal node
    numbering; producers therefore relabel every part back to caller labels
    (via the device intern maps) and offset supernode ids into disjoint
    per-shard ranges before constructing this object.  ``validate()``
    checks the structural half of that contract.
    """

    shards: List[SummaryOutput]

    @property
    def phi(self) -> int:
        """Global objective: per-pair encodings are disjoint across shards."""
        return sum(s.phi for s in self.shards)

    def phi_by_shard(self) -> List[int]:
        return [s.phi for s in self.shards]

    def decode_edges(self) -> Set[Pair]:
        edges: Set[Pair] = set()
        for s in self.shards:
            edges |= s.decode_edges()
        return edges

    def node_count(self) -> int:
        """Distinct nodes across shards (a node may appear in several)."""
        nodes: Set[int] = set()
        for s in self.shards:
            for mem in s.supernodes.values():
                nodes |= mem
        return len(nodes)

    def validate(self) -> "ShardedSummaryOutput":
        """Assert the union-of-parts invariants; returns self for chaining.

        * supernode id ranges are pairwise disjoint across shards (so the
          union never aliases two shards' supernodes), and
        * every part satisfies phi == |P| + |C+| + |C-| by construction
          (``SummaryOutput.phi`` is definitional; here we check each part's
          correction sets stay inside its own supernode universe).
        """
        seen_sids: Set[int] = set()
        for i, s in enumerate(self.shards):
            sids = set(s.supernodes)
            overlap = sids & seen_sids
            assert not overlap, f"shard {i} reuses supernode ids {overlap}"
            seen_sids |= sids
            members: Set[int] = set()
            for mem in s.supernodes.values():
                members |= mem
            for (a, b) in s.superedges:
                assert a in sids and b in sids, \
                    f"shard {i} superedge {(a, b)} leaves its sid range"
            for pair_set, name in ((s.c_plus, "C+"), (s.c_minus, "C-")):
                for (u, v) in pair_set:
                    assert u in members and v in members, \
                        f"shard {i} {name} pair {(u, v)} names a foreign node"
        return self


@dataclass
class StreamStats:
    """Per-run accounting used by benchmarks and EXPERIMENTS.md."""

    changes: int = 0
    insertions: int = 0
    deletions: int = 0
    trials: int = 0
    accepted: int = 0
    escapes: int = 0
    phi_history: List[Tuple[int, int, int]] = field(default_factory=list)  # (t, phi, |E|)
