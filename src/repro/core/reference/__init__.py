from repro.core.reference.algorithms import (ALGORITHMS, MoSSo, MoSSoGreedy,
                                             MoSSoMCMC, MoSSoSimple,
                                             StreamingSummarizer)
from repro.core.reference.dynamic_summary import DynamicSummary
from repro.core.reference.minhash import MinHashClusters
from repro.core.reference.neighbor_sampler import get_random_neighbors
from repro.core.reference.summary_query import SummaryQueryOracle

__all__ = [
    "ALGORITHMS", "MoSSo", "MoSSoGreedy", "MoSSoMCMC", "MoSSoSimple",
    "StreamingSummarizer", "DynamicSummary", "MinHashClusters",
    "get_random_neighbors", "SummaryQueryOracle",
]
