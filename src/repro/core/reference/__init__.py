from repro.core.reference.algorithms import (ALGORITHMS, MoSSo, MoSSoGreedy,
                                             MoSSoMCMC, MoSSoSimple,
                                             StreamingSummarizer)
from repro.core.reference.dynamic_summary import DynamicSummary
from repro.core.reference.minhash import MinHashClusters
from repro.core.reference.neighbor_sampler import get_random_neighbors

__all__ = [
    "ALGORITHMS", "MoSSo", "MoSSoGreedy", "MoSSoMCMC", "MoSSoSimple",
    "StreamingSummarizer", "DynamicSummary", "MinHashClusters",
    "get_random_neighbors",
]
