from repro.core.reference.algorithms import (ALGORITHMS, MoSSo, MoSSoGreedy,
                                             MoSSoMags, MoSSoMCMC,
                                             MoSSoSimple, StreamingSummarizer)
from repro.core.reference.dynamic_summary import (DynamicSummary,
                                                  WeightedDynamicSummary)
from repro.core.reference.minhash import MinHashClusters
from repro.core.reference.neighbor_sampler import get_random_neighbors
from repro.core.reference.summary_query import SummaryQueryOracle
from repro.core.reference.weights import host_node_weight

__all__ = [
    "ALGORITHMS", "MoSSo", "MoSSoGreedy", "MoSSoMCMC", "MoSSoMags",
    "MoSSoSimple", "StreamingSummarizer", "DynamicSummary",
    "WeightedDynamicSummary", "MinHashClusters", "get_random_neighbors",
    "SummaryQueryOracle", "host_node_weight",
]
