"""Incremental min-hash coarse clustering (Careful Selection (2), Sect. 3.5).

Each node's cluster id is the minimum of a universal hash over its current
neighborhood.  Two nodes share a cluster with probability equal to the
Jaccard similarity of their neighborhoods (Broder et al. [5]) — exactly the
"nodes with similar connectivity" signal MoSSo wants for candidate pools.

Updates are O(1) per edge insertion and O(deg) only when the arg-min
neighbor of a node is deleted (rare), matching the paper's claim that
min-hash clusters "can be updated rapidly in response to changes".
"""
from __future__ import annotations

from typing import Dict

from repro.core.reference.dynamic_summary import DynamicSummary

_MASK = (1 << 61) - 1
NO_CLUSTER = _MASK  # nodes with empty neighborhoods match nothing


def _mix(x: int, seed: int) -> int:
    """SplitMix64-style integer hash (deterministic across runs)."""
    x = (x + 0x9E3779B97F4A7C15 + seed * 0xBF58476D1CE4E5B9) & 0xFFFFFFFFFFFFFFFF
    x = ((x ^ (x >> 30)) * 0xBF58476D1CE4E5B9) & 0xFFFFFFFFFFFFFFFF
    x = ((x ^ (x >> 27)) * 0x94D049BB133111EB) & 0xFFFFFFFFFFFFFFFF
    return (x ^ (x >> 31)) & _MASK


class MinHashClusters:
    """Maintains cluster(u) = min_{w in N(u)} h(w) under the edge stream."""

    def __init__(self, seed: int = 0) -> None:
        self.seed = seed
        self.minh: Dict[int, int] = {}

    def hash_node(self, w: int) -> int:
        return _mix(w, self.seed)

    def cluster(self, u: int) -> int:
        return self.minh.get(u, NO_CLUSTER)

    def same_cluster(self, u: int, v: int) -> bool:
        cu = self.cluster(u)
        return cu != NO_CLUSTER and cu == self.cluster(v)

    def _recompute(self, s: DynamicSummary, u: int) -> None:
        nbrs = s.neighbors(u)
        self.minh[u] = min((self.hash_node(w) for w in nbrs), default=NO_CLUSTER)

    def on_insert(self, s: DynamicSummary, u: int, v: int) -> None:
        """Called *after* the summary applied the insertion of {u, v}."""
        self.minh[u] = min(self.minh.get(u, NO_CLUSTER), self.hash_node(v))
        self.minh[v] = min(self.minh.get(v, NO_CLUSTER), self.hash_node(u))

    def on_delete(self, s: DynamicSummary, u: int, v: int) -> None:
        """Called *after* the summary applied the deletion of {u, v}."""
        if self.minh.get(u) == self.hash_node(v):
            self._recompute(s, u)
        if self.minh.get(v) == self.hash_node(u):
            self._recompute(s, v)
