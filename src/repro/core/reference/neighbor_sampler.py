"""GetRandomNeighbor (Alg. 2) — Fast Random (2).

Uniformly samples ``c`` neighbors of ``u`` *from the output representation*
(G*, C) without retrieving all of N(u):

* with prob |C+(u)|/deg(u) draw from the materialized C+ list (Thm. 1 split),
* otherwise run the size-biased MCMC over the P-neighbour supernodes of S_u
  (proposal uniform over k supernodes, acceptance min(1, |S_p|/|S_n|), Thm. 2)
  and rejection-sample a member that is a true neighbor (not in C-(u), != u).

Average cost O(c · (1 + |C-(u)|/deg(u))) per Thm. 3.
"""
from __future__ import annotations

import random
from typing import List

from repro.core.reference.dynamic_summary import DynamicSummary

_MAX_STEPS = 1_000_000  # safety bound; Thm. 3 says expected steps are tiny


def get_random_neighbors(s: DynamicSummary, u: int, c: int,
                         rng: random.Random) -> List[int]:
    """Sample ``c`` neighbors of ``u`` with replacement, uniformly over N(u)."""
    deg = s.deg.get(u, 0)
    if deg == 0:
        return []
    cp = list(s.cplus[u])
    cm = s.cminus[u]
    pn = [sid for sid in s.psn[s.n2s[u]]]
    out: List[int] = []
    if not pn:
        # every neighbor is materialized in C+ (|C+(u)| == deg(u))
        return [rng.choice(cp) for _ in range(c)]
    members = s.members
    s_n = rng.choice(pn)
    steps = 0
    while len(out) < c:
        steps += 1
        assert steps < _MAX_STEPS, "GetRandomNeighbor failed to converge"
        if cp and rng.random() * deg <= len(cp):
            out.append(rng.choice(cp))
            continue
        while True:
            steps += 1
            assert steps < _MAX_STEPS, "GetRandomNeighbor failed to converge"
            s_p = rng.choice(pn)
            if rng.random() <= min(1.0, len(members[s_p]) / len(members[s_n])):
                s_n = s_p
            w = rng.choice(tuple(members[s_n]))
            if w != u and w not in cm:
                out.append(w)
                break
    return out
