"""The four streaming algorithms of the paper (Tier A, faithful).

* :class:`MoSSoGreedy`  — Sect. 3.2, baseline: TP=TN={u}, CP=V, argmin dphi.
* :class:`MoSSoMCMC`    — Sect. 3.3 + Appendix C: TN=N(u), SBM-style proposal
  (Eq. 4) and Metropolis–Hastings acceptance (Eq. 5).
* :class:`MoSSoSimple`  — Sect. 3.4 / Alg. 1 blue lines: c samples from N(u),
  1/deg testing filter, corrective escape, CP(y)=N(u).
* :class:`MoSSo`        — Sect. 3.5 / Alg. 1 red lines: GetRandomNeighbor
  sampling on the representation, min-hash coarse clusters, CP=TP ∩ R(y).

All share the trial skeleton of Fig. 3 and accept a proposal iff dphi <= 0
(Alg. 1 line 16).
"""
from __future__ import annotations

import math
import random
from typing import Dict, Iterable, List, Optional, Sequence, Tuple

from repro.core.reference.dynamic_summary import DynamicSummary
from repro.core.reference.minhash import MinHashClusters
from repro.core.reference.neighbor_sampler import get_random_neighbors
from repro.core.summary import StreamStats

Change = Tuple[int, int, bool]  # (u, v, is_insert)


class StreamingSummarizer:
    """Common driver: apply each change, then run trials for both endpoints."""

    name = "base"

    def __init__(self, seed: int = 0) -> None:
        self.s = DynamicSummary()
        self.rng = random.Random(seed)
        self.stats = StreamStats()

    # -- hooks ---------------------------------------------------------------
    def on_change(self, u: int, v: int, is_insert: bool) -> None:
        """Update auxiliary structures (e.g. coarse clusters)."""

    def trials(self, u: int) -> None:
        raise NotImplementedError

    # -- driver ---------------------------------------------------------------
    def process(self, u: int, v: int, is_insert: bool) -> None:
        if is_insert:
            self.s.insert(u, v)
            self.stats.insertions += 1
        else:
            self.s.delete(u, v)
            self.stats.deletions += 1
        self.stats.changes += 1
        self.on_change(u, v, is_insert)
        self.trials(u)
        self.trials(v)

    def run(self, stream: Iterable[Change], record_every: int = 0) -> StreamStats:
        for (u, v, ins) in stream:
            self.process(u, v, ins)
            if record_every and self.stats.changes % record_every == 0:
                self.stats.phi_history.append(
                    (self.stats.changes, self.s.phi, self.s.num_edges))
        return self.stats

    # -- shared trial pieces ---------------------------------------------------
    def _attempt(self, y: int, target: Optional[int],
                 h: Optional[Dict[int, int]] = None) -> bool:
        """One Move-if-Saved-Stay-otherwise acceptance test; target None=escape."""
        self.stats.trials += 1
        s = self.s
        if target is None:
            if len(s.members[s.n2s[y]]) <= 1:
                return False  # already a singleton; escape is a no-op
            target = s.new_sid()
            d = s.delta_phi(y, target, h)
            if d <= 0:
                s.move(y, target)
                self.stats.accepted += 1
                self.stats.escapes += 1
                return True
            return False
        if target == s.n2s[y]:
            return False
        d = s.delta_phi(y, target, h)
        if d <= 0:
            s.move(y, target)
            self.stats.accepted += 1
            return True
        return False


class MoSSoGreedy(StreamingSummarizer):
    """Sect. 3.2: exhaustively pick the best destination for the input node."""

    name = "mosso-greedy"

    def trials(self, u: int) -> None:
        s = self.s
        if u not in s.n2s:
            return
        h = s.move_hist(u)
        best_d, best_t = 0, None
        for sid in list(s.members):
            if sid == s.n2s[u]:
                continue
            d = s.delta_phi(u, sid, h)
            if d < best_d:
                best_d, best_t = d, sid
        self.stats.trials += 1
        if best_t is not None:
            s.move(u, best_t)
            self.stats.accepted += 1


class MoSSoMCMC(StreamingSummarizer):
    """Sect. 3.3 + Appendix C: SBM-flavoured proposal + MH acceptance."""

    name = "mosso-mcmc"

    def __init__(self, seed: int = 0, beta: float = 10.0, eps: float = 1.0) -> None:
        super().__init__(seed)
        self.beta = beta
        self.eps = eps

    def _row_sum(self, sid: int) -> int:
        s = self.s
        return sum(s._count(sid, x) for x in s.sn.get(sid, ()))

    def _proposal(self, s_x: int) -> int:
        """Draw S_z with prob (|E_{S_z,S_x}| + eps) / (|E_{S_x}| + eps |S|), Eq. 4."""
        s = self.s
        sids = list(s.members)
        k = len(sids)
        row = self._row_sum(s_x)
        tot = row + self.eps * k
        r = self.rng.random() * tot
        if r >= row:  # epsilon mass: uniform over all supernodes
            return self.rng.choice(sids)
        acc = 0.0
        for x in s.sn.get(s_x, ()):
            acc += s._count(s_x, x)
            if r < acc:
                return x
        return self.rng.choice(sids)

    def _prop_prob(self, target: int, s_x: int, k: int) -> float:
        row = self._row_sum(s_x)
        return (self.s._count(target, s_x) + self.eps) / (row + self.eps * k)

    def trials(self, u: int) -> None:
        s = self.s
        if u not in s.n2s or s.deg.get(u, 0) == 0:
            return
        for y in sorted(s.neighbors(u)):
            self.stats.trials += 1
            nbrs_y = sorted(s.neighbors(y))
            if not nbrs_y:
                continue
            x = self.rng.choice(nbrs_y)
            s_z = self._proposal(s.n2s[x])
            a = s.n2s[y]
            if s_z == a:
                continue
            h = s.neighbor_hist(y)
            d = s.delta_phi(y, s_z)   # h is count-based; delta_phi self-hists
            # Eq. 5 forward/backward proposal mixtures over S_x of y's nbrs.
            k = len(s.members)
            p_sx = {sid: cnt / len(nbrs_y) for sid, cnt in h.items()}
            fwd = sum(p * self._prop_prob(s_z, sx, k) for sx, p in p_sx.items())
            # backward prob must be evaluated *after* the move (Appendix C);
            # move() is exact and revertible so simulate it.
            s.move(y, s_z)
            k2 = len(s.members)
            exists_a = a in s.members
            bwd = 0.0
            if exists_a:
                h2 = s.neighbor_hist(y)
                p2 = {sid: cnt / len(nbrs_y) for sid, cnt in h2.items()}
                bwd = sum(p * self._prop_prob(a, sx, k2) for sx, p in p2.items())
            ratio = (bwd / fwd) if fwd > 0 else 1.0
            accept_p = min(1.0, math.exp(min(50.0, -self.beta * d)) * ratio) \
                if exists_a else (1.0 if d <= 0 else 0.0)
            if self.rng.random() <= accept_p:
                self.stats.accepted += 1
            else:
                s.move(y, a)  # revert


class MoSSoSimple(StreamingSummarizer):
    """Sect. 3.4 (Alg. 1, blue lines)."""

    name = "mosso-simple"

    def __init__(self, seed: int = 0, escape: float = 0.3, c: int = 120) -> None:
        super().__init__(seed)
        self.escape = escape
        self.c = c

    def _testing_nodes(self, tp: Sequence[int]) -> List[int]:
        return [w for w in tp if self.rng.random() * self.s.deg.get(w, 1) <= 1.0]

    def trials(self, u: int) -> None:
        s = self.s
        if u not in s.n2s or s.deg.get(u, 0) == 0:
            return
        nbrs = sorted(s.neighbors(u))
        tp = [self.rng.choice(nbrs) for _ in range(self.c)]
        for y in self._testing_nodes(tp):
            if self.rng.random() <= self.escape:
                self._attempt(y, None)
            else:
                z = self.rng.choice(nbrs)  # CP(y) = N(u)
                self._attempt(y, s.n2s[z])


class MoSSo(StreamingSummarizer):
    """Sect. 3.5 (Alg. 1, red lines) — the full-fledged proposed method."""

    name = "mosso"

    def __init__(self, seed: int = 0, escape: float = 0.3, c: int = 120,
                 minhash_seed: int = 0) -> None:
        super().__init__(seed)
        self.escape = escape
        self.c = c
        self.clusters = MinHashClusters(minhash_seed)

    def on_change(self, u: int, v: int, is_insert: bool) -> None:
        if is_insert:
            self.clusters.on_insert(self.s, u, v)
        else:
            self.clusters.on_delete(self.s, u, v)

    def trials(self, u: int) -> None:
        s = self.s
        if u not in s.n2s or s.deg.get(u, 0) == 0:
            return
        tp = get_random_neighbors(s, u, self.c, self.rng)
        for y in tp:
            if self.rng.random() * s.deg.get(y, 1) > 1.0:
                continue  # 1/deg(w) testing filter
            if self.rng.random() <= self.escape:
                self._attempt(y, None)
            else:
                cp = [z for z in tp if self.clusters.same_cluster(y, z)]
                if not cp:
                    continue
                z = self.rng.choice(cp)
                self._attempt(y, s.n2s[z])


class MoSSoMags(StreamingSummarizer):
    """Mags-DM-style candidate scheme on the MoSSo trial skeleton.

    Host reference for the engine's ``proposal="magsdm"``: the candidate
    destination is the MODAL supernode among the TP samples (the densest
    co-sampled destination, ties to the smallest sid), replacing the
    min-hash CP(y) pick.  TP sampling, the 1/deg testing filter, the
    corrective escape, and Move-if-Saved acceptance are unchanged.  The
    deviation vs the published Mags-DM heuristic is audited in
    ``docs/KNOWN_ISSUES.md``.
    """

    name = "mosso-mags"

    def __init__(self, seed: int = 0, escape: float = 0.3, c: int = 120) -> None:
        super().__init__(seed)
        self.escape = escape
        self.c = c

    def trials(self, u: int) -> None:
        s = self.s
        if u not in s.n2s or s.deg.get(u, 0) == 0:
            return
        tp = get_random_neighbors(s, u, self.c, self.rng)
        for y in tp:
            if self.rng.random() * s.deg.get(y, 1) > 1.0:
                continue  # 1/deg(w) testing filter
            if self.rng.random() <= self.escape:
                self._attempt(y, None)
            else:
                a = s.n2s[y]
                cnt: Dict[int, int] = {}
                for z in tp:
                    sz = s.n2s[z]
                    if sz != a:
                        cnt[sz] = cnt.get(sz, 0) + 1
                if not cnt:
                    continue
                target = max(cnt, key=lambda sid: (cnt[sid], -sid))
                self._attempt(y, target)


ALGORITHMS = {
    "greedy": MoSSoGreedy,
    "mcmc": MoSSoMCMC,
    "simple": MoSSoSimple,
    "mosso": MoSSo,
    "mags": MoSSoMags,
}
