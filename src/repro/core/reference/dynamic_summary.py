"""Faithful dynamic lossless-summary state machine (Tier A).

This module maintains the *exact* output representation of the paper —
summary graph ``G* = (S, P)`` and corrections ``C = (C+, C-)`` — under three
mutations:

* ``insert(u, v)`` / ``delete(u, v)``: one change of the fully dynamic stream,
* ``move(y, target_sid)``: move node ``y`` into another supernode (the basic
  step of every MoSSo variant, Sect. 3.1).

Faithfulness notes
------------------
* Neighborhoods are retrieved from the representation itself exactly as in
  Lemma 1 (C+(u) ∪ members of P-neighbours of S_u, minus C-(u)); the raw edge
  set is never stored.  Memory is therefore O(|V| + |P| + |C+| + |C-|) plus
  the per-pair edge counts ``E_AB`` that the paper's own implementation also
  keeps (proof of Thm. 4).
* ``phi`` is maintained incrementally and equals |P| + |C+| + |C-| at all
  times (asserted in tests against the materialized representation).
* ``delta_phi(y, target)`` is the closed-form objective change of a move used
  by all algorithm variants; tests check it equals the phi difference of
  actually applying the move.

Objective hooks
---------------
All phi accounting below is written in the WEIGHTED generalization — per
pair, the live weight ``W_AB`` against the total pair weight ``TW_AB``,
with the optimal rule ``cost(W, TW) = min(W, TW - W + 1)`` unchanged —
routed through overridable ``_w*`` hooks.  The base class's hooks return
the unweighted counts (w(u) = 1, W = E, TW = T), making it *literally the
same integers* as the historical exact-objective code; the
:class:`WeightedDynamicSummary` subclass supplies hashed node weights and
is the host reference for the engine's ``objective="weighted"``
(``tests/test_policies.py`` pins the uniform-weights bit-identity).
"""
from __future__ import annotations

import itertools
from typing import Callable, Dict, Iterable, List, Optional, Set, Tuple

from repro.core.summary import (Pair, SummaryOutput, encoding_cost,
                                is_superedge, pair_key, t_count)


def _wtri(sw: int, sq: int) -> int:
    """Total self-pair weight (SW^2 - SQ) / 2; equals T(s, s) when w == 1."""
    return (sw * sw - sq) // 2


class DynamicSummary:
    """Incrementally maintained (G*, C) with optimal per-pair encoding."""

    def __init__(self) -> None:
        self.n2s: Dict[int, int] = {}                # node -> supernode id
        self.members: Dict[int, Set[int]] = {}       # sid -> nodes
        self.deg: Dict[int, int] = {}                # node degree in G
        self.eab: Dict[Pair, int] = {}               # pair -> |E_AB| (>0 only)
        self.sn: Dict[int, Set[int]] = {}            # sid -> sids with E>0
        self.P: Set[Pair] = set()                    # superedges
        self.psn: Dict[int, Set[int]] = {}           # sid -> P-neighbour sids
        self.cplus: Dict[int, Set[int]] = {}         # node -> C+ neighbours
        self.cminus: Dict[int, Set[int]] = {}        # node -> C- neighbours
        self.phi: int = 0
        self.num_edges: int = 0
        self._next_sid: int = 0

    # ------------------------------------------------------------------ nodes
    def ensure_node(self, u: int) -> None:
        if u in self.n2s:
            return
        sid = self._next_sid
        self._next_sid += 1
        self.n2s[u] = sid
        self.members[sid] = {u}
        self.deg[u] = 0
        self.sn[sid] = set()
        self.psn[sid] = set()
        self.cplus[u] = set()
        self.cminus[u] = set()

    def supernode_of(self, u: int) -> int:
        return self.n2s[u]

    def size(self, sid: int) -> int:
        return len(self.members[sid])

    # -------------------------------------------------------------- internals
    def _t(self, a: int, b: int) -> int:
        return t_count(len(self.members[a]), len(self.members[b]), a == b)

    def _count(self, a: int, b: int) -> int:
        return self.eab.get(pair_key(a, b), 0)

    # ------------------------------------------------------- objective hooks
    # The base hooks realize the exact objective: weight 1 per node, so
    # every weighted quantity collapses to its count (same ints, same phi).
    def _w(self, u: int) -> int:
        """Node weight w(u)."""
        return 1

    def _wcount(self, a: int, b: int) -> int:
        """W_AB = sum of w(u)w(v) over live edges of the pair."""
        return self._count(a, b)

    def _bump_wcount(self, a: int, b: int, delta: int) -> None:
        """Maintain W_AB on edge add/remove (no-op when W == E)."""

    def _wsize(self, sid: int) -> int:
        """SW = sum of member weights."""
        return len(self.members.get(sid, ()))

    def _wsq(self, sid: int) -> int:
        """SQ = sum of squared member weights."""
        return len(self.members.get(sid, ()))

    def _wt(self, a: int, b: int) -> int:
        """TW_AB = total pair weight (T under uniform weights)."""
        return self._t(a, b)

    def move_hist(self, y: int) -> Dict[int, int]:
        """Per-supernode mass of y's edges under the objective: the input
        ``h`` of :meth:`delta_phi` (weighted: w(y)w(nbr) sums; exact:
        :meth:`neighbor_hist` counts)."""
        return self.neighbor_hist(y)

    def _member_pairs(self, a: int, b: int) -> Iterable[Pair]:
        if a == b:
            return itertools.combinations(sorted(self.members[a]), 2)
        return itertools.product(sorted(self.members[a]), sorted(self.members[b]))

    def _edge_list(self, a: int, b: int) -> List[Pair]:
        """Recover E_AB from the current encoding of pair (a, b)."""
        p = pair_key(a, b)
        if p in self.P:
            return [(u, v) for (u, v) in self._member_pairs(a, b)
                    if v not in self.cminus[u]]
        # C+ mode: walk the smaller side.
        if a == b:
            mem = self.members[a]
            out = []
            for u in mem:
                for v in self.cplus[u]:
                    if v in mem and u < v:
                        out.append((u, v))
            return out
        if len(self.members[a]) > len(self.members[b]):
            a, b = b, a
        memb = self.members[b]
        return [(u, v) for u in self.members[a] for v in self.cplus[u] if v in memb]

    def _set_count(self, a: int, b: int, new: int) -> None:
        """Update E_AB and the supernode-adjacency index; phi via callers."""
        p = pair_key(a, b)
        old = self.eab.get(p, 0)
        if new == old:
            return
        if new == 0:
            self.eab.pop(p, None)
            if old > 0:
                self.sn[a].discard(b)
                self.sn[b].discard(a)
        else:
            self.eab[p] = new
            if old == 0:
                self.sn[a].add(b)
                self.sn[b].add(a)

    def _reencode(self, a: int, b: int) -> None:
        """Flip the materialized encoding of pair (a,b) if the rule says so.

        phi is *not* touched here: cost() is mode-independent (the min).
        """
        p = pair_key(a, b)
        want = is_superedge(self._wcount(a, b), self._wt(a, b))
        have = p in self.P
        if want == have:
            return
        edges = self._edge_list(a, b)
        if want:
            for (u, v) in edges:
                self.cplus[u].discard(v)
                self.cplus[v].discard(u)
            eset = {pair_key(u, v) for (u, v) in edges}
            self.P.add(p)
            self.psn[a].add(b)
            self.psn[b].add(a)
            for (u, v) in self._member_pairs(a, b):
                if pair_key(u, v) not in eset:
                    self.cminus[u].add(v)
                    self.cminus[v].add(u)
        else:
            self.P.discard(p)
            self.psn[a].discard(b)
            self.psn[b].discard(a)
            for (u, v) in self._member_pairs(a, b):
                self.cminus[u].discard(v)
                self.cminus[v].discard(u)
            for (u, v) in edges:
                self.cplus[u].add(v)
                self.cplus[v].add(u)

    def _add_edge_encoding(self, u: int, v: int) -> None:
        a, b = self.n2s[u], self.n2s[v]
        tw = self._wt(a, b)
        w = self._wcount(a, b)
        wuv = self._w(u) * self._w(v)
        self.phi += encoding_cost(w + wuv, tw) - encoding_cost(w, tw)
        if pair_key(a, b) in self.P:
            self.cminus[u].discard(v)
            self.cminus[v].discard(u)
        else:
            self.cplus[u].add(v)
            self.cplus[v].add(u)
        self._set_count(a, b, self._count(a, b) + 1)
        self._bump_wcount(a, b, wuv)
        self._reencode(a, b)

    def _remove_edge_encoding(self, u: int, v: int) -> None:
        a, b = self.n2s[u], self.n2s[v]
        tw = self._wt(a, b)
        w = self._wcount(a, b)
        wuv = self._w(u) * self._w(v)
        self.phi += encoding_cost(w - wuv, tw) - encoding_cost(w, tw)
        if pair_key(a, b) in self.P:
            self.cminus[u].add(v)
            self.cminus[v].add(u)
        else:
            self.cplus[u].discard(v)
            self.cplus[v].discard(u)
        self._set_count(a, b, self._count(a, b) - 1)
        self._bump_wcount(a, b, -wuv)
        self._reencode(a, b)

    # ------------------------------------------------------------ stream ops
    def insert(self, u: int, v: int) -> None:
        assert u != v, "self-loops are excluded (simple graph)"
        self.ensure_node(u)
        self.ensure_node(v)
        assert not self.has_edge(u, v), f"insert of existing edge {(u, v)}"
        self._add_edge_encoding(u, v)
        self.deg[u] += 1
        self.deg[v] += 1
        self.num_edges += 1

    def delete(self, u: int, v: int) -> None:
        assert self.has_edge(u, v), f"delete of missing edge {(u, v)}"
        self._remove_edge_encoding(u, v)
        self.deg[u] -= 1
        self.deg[v] -= 1
        self.num_edges -= 1

    # --------------------------------------------------------------- queries
    def has_edge(self, u: int, v: int) -> bool:
        """O(1)-ish membership test on the representation (Sect. 3.5)."""
        if u not in self.n2s or v not in self.n2s:
            return False
        if v in self.cminus[u]:
            return False
        return v in self.cplus[u] or pair_key(self.n2s[u], self.n2s[v]) in self.P

    def neighbors(self, u: int) -> Set[int]:
        """Lemma-1 neighborhood retrieval from (G*, C) in O(deg + |C-(u)|)."""
        res = set(self.cplus[u])
        for sid in self.psn[self.n2s[u]]:
            res |= self.members[sid]
        res.discard(u)
        res -= self.cminus[u]
        return res

    # ----------------------------------------------------------------- moves
    def neighbor_hist(self, y: int) -> Dict[int, int]:
        """h[X] = |N(y) ∩ X| per supernode X (reused across candidate scans)."""
        h: Dict[int, int] = {}
        for w in self.neighbors(y):
            s = self.n2s[w]
            h[s] = h.get(s, 0) + 1
        return h

    def _pair_updates(self, y: int, target: int,
                      h: Optional[Dict[int, int]] = None,
                      ) -> Dict[Pair, Tuple[int, int, int, int]]:
        """Per-pair (W_old, TW_old, W_new, TW_new) induced by moving
        y -> target (counts E/T under the base hooks).

        ``target`` may be a not-yet-existing sid (escape to fresh singleton),
        signalled by target not in ``self.members``.
        """
        a = self.n2s[y]
        wy = self._w(y)
        swa, sqa = self._wsize(a), self._wsq(a)
        fresh = target not in self.members
        swb = 0 if fresh else self._wsize(target)
        sqb = 0 if fresh else self._wsq(target)
        if h is None:
            h = self.move_hist(y)

        out: Dict[Pair, Tuple[int, int, int, int]] = {}
        others = (set(self.sn.get(a, ())) | set(self.sn.get(target, ())) |
                  set(h)) - {a, target}
        for x in others:
            swx = self._wsize(x)
            w_ax = self._wcount(a, x)
            out[pair_key(a, x)] = (w_ax, swa * swx,
                                   w_ax - h.get(x, 0), (swa - wy) * swx)
            w_bx = 0 if fresh else self._wcount(target, x)
            out[pair_key(target, x)] = (w_bx, swb * swx,
                                        w_bx + h.get(x, 0), (swb + wy) * swx)
        w_aa = self._wcount(a, a)
        out[(a, a)] = (w_aa, _wtri(swa, sqa),
                       w_aa - h.get(a, 0), _wtri(swa - wy, sqa - wy * wy))
        w_bb = 0 if fresh else self._wcount(target, target)
        out[(target, target)] = (w_bb, _wtri(swb, sqb),
                                 w_bb + h.get(target, 0),
                                 _wtri(swb + wy, sqb + wy * wy))
        w_ab = 0 if fresh else self._wcount(a, target)
        out[pair_key(a, target)] = (w_ab, swa * swb,
                                    w_ab - h.get(target, 0) + h.get(a, 0),
                                    (swa - wy) * (swb + wy))
        return out

    def delta_phi(self, y: int, target: int,
                  h: Optional[Dict[int, int]] = None) -> int:
        """Closed-form change in phi if node y moved into supernode ``target``.

        This is the paper's "computing savings in the objective" step
        (Sect. 3.6.3): only pairs touching SN(S_y) ∪ SN(S_z) matter.
        Pass a precomputed ``move_hist(y)`` when scanning many candidates
        (NOT ``neighbor_hist`` — they differ under weighted objectives).
        """
        if target in self.members and self.n2s[y] == target:
            return 0
        d = 0
        for (e0, t0, e1, t1) in self._pair_updates(y, target, h).values():
            d += encoding_cost(e1, t1) - encoding_cost(e0, t0)
        return d

    def new_sid(self) -> int:
        sid = self._next_sid
        self._next_sid += 1
        return sid

    def move(self, y: int, target: int) -> None:
        """Unconditionally move y into supernode ``target`` (created if new)."""
        a = self.n2s[y]
        if target == a:
            return
        if target not in self.members:
            self.members[target] = set()
            self.sn[target] = set()
            self.psn[target] = set()
            self._next_sid = max(self._next_sid, target + 1)
        nbrs = sorted(self.neighbors(y))
        # 1. detach y's edges from the encoding (degree unchanged).
        for w in nbrs:
            self._remove_edge_encoding(y, w)
        # 1b. y leaves the scope of A's superedges: after the detach, y's
        # C- entries are exactly the potential pairs covered by P at A —
        # they stop existing once y departs (phi is count-derived; the
        # matching cost change is applied in step 3's re-costing).
        for q in list(self.cminus[y]):
            self.cminus[q].discard(y)
        self.cminus[y].clear()
        # 2. membership switch.
        self.members[a].remove(y)
        self.members[target].add(y)
        self.n2s[y] = target
        # 2b. y enters the scope of B's superedges: y currently has no
        # encoded edges, so every potential pair covered by a superedge of
        # B is a non-edge and must appear in C- until step 5 re-attaches.
        for x in list(self.psn.get(target, ())):
            for q in self.members[x]:
                if q != y:
                    self.cminus[y].add(q)
                    self.cminus[q].add(y)
        # 3. re-cost every pair of A and B: TW changed with the weight sums.
        touched = set()
        for x in list(self.sn.get(a, ())) + [a]:
            touched.add(pair_key(a, x))
        for x in list(self.sn.get(target, ())) + [target]:
            touched.add(pair_key(target, x))
        wy = self._w(y)
        dw = {a: wy, target: -wy}
        dq = {a: wy * wy, target: -wy * wy}
        for (p, q) in touched:
            if self._count(p, q) <= 0:
                continue
            # phi was accounted with the OLD TW; recompute with new sums.
            # Note: old TW differs only for pairs involving a or target.
            sw_p = self._wsize(p) + dw.get(p, 0)
            sw_q = self._wsize(q) + dw.get(q, 0)
            if p == q:
                tw_old = _wtri(sw_p, self._wsq(p) + dq.get(p, 0))
            else:
                tw_old = sw_p * sw_q
            tw_new = self._wt(p, q)
            w = self._wcount(p, q)
            self.phi += encoding_cost(w, tw_new) - encoding_cost(w, tw_old)
            self._reencode(p, q)
        # 4. drop A if emptied (all its counts are 0: y was its only member).
        if not self.members[a]:
            assert not self.sn[a], "empty supernode still has edge counts"
            del self.members[a]
            del self.sn[a]
            del self.psn[a]
        # 5. re-attach y's edges under the new membership.
        for w in nbrs:
            self._add_edge_encoding(y, w)

    # ------------------------------------------------------------- materialize
    def materialize(self) -> SummaryOutput:
        cp = set()
        cm = set()
        for u, s in self.cplus.items():
            for v in s:
                cp.add(pair_key(u, v))
        for u, s in self.cminus.items():
            for v in s:
                cm.add(pair_key(u, v))
        return SummaryOutput(
            supernodes={sid: set(m) for sid, m in self.members.items()},
            superedges=set(self.P),
            c_plus=cp,
            c_minus=cm,
        )

    def phi_recomputed(self) -> int:
        """Independent phi from the live pair table (tests cross-check).

        Uses the objective hooks, so under the weighted subclass this
        refolds ``cost(W, TW)`` — the weighted phi.
        """
        tot = 0
        for (a, b) in self.eab:
            tot += encoding_cost(self._wcount(a, b), self._wt(a, b))
        return tot

    def compression_ratio(self) -> float:
        """phi / |E| — Eq. (3) under the exact objective; the weighted
        analog (objective mass per live edge) under weighted hooks."""
        if self.num_edges == 0:
            return 0.0
        return self.phi / self.num_edges

    def representation_size(self) -> int:
        """|V| + |P| + |C+| + |C-| (Thm. 4 memory measure)."""
        return len(self.n2s) + self.phi


class WeightedDynamicSummary(DynamicSummary):
    """Utility-weighted host reference (the engine's ``objective="weighted"``).

    phi = |P| + sum_{C+} w(u)w(v) + sum_{C-} w(u)w(v): superedges cost 1,
    corrections cost their pair weight, and the per-pair optimum is
    ``cost(W_AB, TW_AB)`` with the same closed form as the exact rule
    (arxiv 2006.08949's utility view).  Decoding stays LOSSLESS — weights
    only shift which encoding mode each pair prefers.

    ``node_weight`` defaults to the engine's hashed weights
    (:func:`repro.core.reference.weights.host_node_weight` on the node id);
    pass an explicit callable to weigh caller labels through an intern map
    when differencing against device state.  ``weight_levels <= 1`` makes
    every hook collapse to the base class — bit-identical to the exact
    objective (the property test in ``tests/test_policies.py``).
    """

    def __init__(self, weight_levels: int = 0,
                 node_weight: Optional[Callable[[int], int]] = None) -> None:
        super().__init__()
        if node_weight is None:
            from repro.core.reference.weights import host_node_weight
            node_weight = lambda u: host_node_weight(u, weight_levels)
        self.weight_levels = weight_levels
        self._node_weight = node_weight
        self._wcache: Dict[int, int] = {}
        self.wab: Dict[Pair, int] = {}               # pair -> W_AB (>0 only)

    def _w(self, u: int) -> int:
        w = self._wcache.get(u)
        if w is None:
            w = int(self._node_weight(u))
            assert w >= 1, f"node weights must be positive, got w({u})={w}"
            self._wcache[u] = w
        return w

    def _wcount(self, a: int, b: int) -> int:
        return self.wab.get(pair_key(a, b), 0)

    def _bump_wcount(self, a: int, b: int, delta: int) -> None:
        p = pair_key(a, b)
        new = self.wab.get(p, 0) + delta
        if new:
            self.wab[p] = new
        else:
            self.wab.pop(p, None)

    def _wsize(self, sid: int) -> int:
        return sum(self._w(u) for u in self.members.get(sid, ()))

    def _wsq(self, sid: int) -> int:
        return sum(self._w(u) ** 2 for u in self.members.get(sid, ()))

    def _wt(self, a: int, b: int) -> int:
        if a == b:
            return _wtri(self._wsize(a), self._wsq(a))
        return self._wsize(a) * self._wsize(b)

    def move_hist(self, y: int) -> Dict[int, int]:
        wy = self._w(y)
        h: Dict[int, int] = {}
        for n in self.neighbors(y):
            s = self.n2s[n]
            h[s] = h.get(s, 0) + wy * self._w(n)
        return h
