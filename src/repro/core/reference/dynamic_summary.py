"""Faithful dynamic lossless-summary state machine (Tier A).

This module maintains the *exact* output representation of the paper —
summary graph ``G* = (S, P)`` and corrections ``C = (C+, C-)`` — under three
mutations:

* ``insert(u, v)`` / ``delete(u, v)``: one change of the fully dynamic stream,
* ``move(y, target_sid)``: move node ``y`` into another supernode (the basic
  step of every MoSSo variant, Sect. 3.1).

Faithfulness notes
------------------
* Neighborhoods are retrieved from the representation itself exactly as in
  Lemma 1 (C+(u) ∪ members of P-neighbours of S_u, minus C-(u)); the raw edge
  set is never stored.  Memory is therefore O(|V| + |P| + |C+| + |C-|) plus
  the per-pair edge counts ``E_AB`` that the paper's own implementation also
  keeps (proof of Thm. 4).
* ``phi`` is maintained incrementally and equals |P| + |C+| + |C-| at all
  times (asserted in tests against the materialized representation).
* ``delta_phi(y, target)`` is the closed-form objective change of a move used
  by all algorithm variants; tests check it equals the phi difference of
  actually applying the move.
"""
from __future__ import annotations

import itertools
from typing import Dict, Iterable, List, Optional, Set, Tuple

from repro.core.summary import (Pair, SummaryOutput, encoding_cost,
                                is_superedge, pair_key, t_count)


class DynamicSummary:
    """Incrementally maintained (G*, C) with optimal per-pair encoding."""

    def __init__(self) -> None:
        self.n2s: Dict[int, int] = {}                # node -> supernode id
        self.members: Dict[int, Set[int]] = {}       # sid -> nodes
        self.deg: Dict[int, int] = {}                # node degree in G
        self.eab: Dict[Pair, int] = {}               # pair -> |E_AB| (>0 only)
        self.sn: Dict[int, Set[int]] = {}            # sid -> sids with E>0
        self.P: Set[Pair] = set()                    # superedges
        self.psn: Dict[int, Set[int]] = {}           # sid -> P-neighbour sids
        self.cplus: Dict[int, Set[int]] = {}         # node -> C+ neighbours
        self.cminus: Dict[int, Set[int]] = {}        # node -> C- neighbours
        self.phi: int = 0
        self.num_edges: int = 0
        self._next_sid: int = 0

    # ------------------------------------------------------------------ nodes
    def ensure_node(self, u: int) -> None:
        if u in self.n2s:
            return
        sid = self._next_sid
        self._next_sid += 1
        self.n2s[u] = sid
        self.members[sid] = {u}
        self.deg[u] = 0
        self.sn[sid] = set()
        self.psn[sid] = set()
        self.cplus[u] = set()
        self.cminus[u] = set()

    def supernode_of(self, u: int) -> int:
        return self.n2s[u]

    def size(self, sid: int) -> int:
        return len(self.members[sid])

    # -------------------------------------------------------------- internals
    def _t(self, a: int, b: int) -> int:
        return t_count(len(self.members[a]), len(self.members[b]), a == b)

    def _count(self, a: int, b: int) -> int:
        return self.eab.get(pair_key(a, b), 0)

    def _member_pairs(self, a: int, b: int) -> Iterable[Pair]:
        if a == b:
            return itertools.combinations(sorted(self.members[a]), 2)
        return itertools.product(sorted(self.members[a]), sorted(self.members[b]))

    def _edge_list(self, a: int, b: int) -> List[Pair]:
        """Recover E_AB from the current encoding of pair (a, b)."""
        p = pair_key(a, b)
        if p in self.P:
            return [(u, v) for (u, v) in self._member_pairs(a, b)
                    if v not in self.cminus[u]]
        # C+ mode: walk the smaller side.
        if a == b:
            mem = self.members[a]
            out = []
            for u in mem:
                for v in self.cplus[u]:
                    if v in mem and u < v:
                        out.append((u, v))
            return out
        if len(self.members[a]) > len(self.members[b]):
            a, b = b, a
        memb = self.members[b]
        return [(u, v) for u in self.members[a] for v in self.cplus[u] if v in memb]

    def _set_count(self, a: int, b: int, new: int) -> None:
        """Update E_AB and the supernode-adjacency index; phi via callers."""
        p = pair_key(a, b)
        old = self.eab.get(p, 0)
        if new == old:
            return
        if new == 0:
            self.eab.pop(p, None)
            if old > 0:
                self.sn[a].discard(b)
                self.sn[b].discard(a)
        else:
            self.eab[p] = new
            if old == 0:
                self.sn[a].add(b)
                self.sn[b].add(a)

    def _reencode(self, a: int, b: int) -> None:
        """Flip the materialized encoding of pair (a,b) if the rule says so.

        phi is *not* touched here: cost() is mode-independent (the min).
        """
        p = pair_key(a, b)
        e = self._count(a, b)
        want = is_superedge(e, self._t(a, b))
        have = p in self.P
        if want == have:
            return
        edges = self._edge_list(a, b)
        if want:
            for (u, v) in edges:
                self.cplus[u].discard(v)
                self.cplus[v].discard(u)
            eset = {pair_key(u, v) for (u, v) in edges}
            self.P.add(p)
            self.psn[a].add(b)
            self.psn[b].add(a)
            for (u, v) in self._member_pairs(a, b):
                if pair_key(u, v) not in eset:
                    self.cminus[u].add(v)
                    self.cminus[v].add(u)
        else:
            self.P.discard(p)
            self.psn[a].discard(b)
            self.psn[b].discard(a)
            for (u, v) in self._member_pairs(a, b):
                self.cminus[u].discard(v)
                self.cminus[v].discard(u)
            for (u, v) in edges:
                self.cplus[u].add(v)
                self.cplus[v].add(u)

    def _add_edge_encoding(self, u: int, v: int) -> None:
        a, b = self.n2s[u], self.n2s[v]
        t = self._t(a, b)
        e = self._count(a, b)
        self.phi += encoding_cost(e + 1, t) - encoding_cost(e, t)
        if pair_key(a, b) in self.P:
            self.cminus[u].discard(v)
            self.cminus[v].discard(u)
        else:
            self.cplus[u].add(v)
            self.cplus[v].add(u)
        self._set_count(a, b, e + 1)
        self._reencode(a, b)

    def _remove_edge_encoding(self, u: int, v: int) -> None:
        a, b = self.n2s[u], self.n2s[v]
        t = self._t(a, b)
        e = self._count(a, b)
        self.phi += encoding_cost(e - 1, t) - encoding_cost(e, t)
        if pair_key(a, b) in self.P:
            self.cminus[u].add(v)
            self.cminus[v].add(u)
        else:
            self.cplus[u].discard(v)
            self.cplus[v].discard(u)
        self._set_count(a, b, e - 1)
        self._reencode(a, b)

    # ------------------------------------------------------------ stream ops
    def insert(self, u: int, v: int) -> None:
        assert u != v, "self-loops are excluded (simple graph)"
        self.ensure_node(u)
        self.ensure_node(v)
        assert not self.has_edge(u, v), f"insert of existing edge {(u, v)}"
        self._add_edge_encoding(u, v)
        self.deg[u] += 1
        self.deg[v] += 1
        self.num_edges += 1

    def delete(self, u: int, v: int) -> None:
        assert self.has_edge(u, v), f"delete of missing edge {(u, v)}"
        self._remove_edge_encoding(u, v)
        self.deg[u] -= 1
        self.deg[v] -= 1
        self.num_edges -= 1

    # --------------------------------------------------------------- queries
    def has_edge(self, u: int, v: int) -> bool:
        """O(1)-ish membership test on the representation (Sect. 3.5)."""
        if u not in self.n2s or v not in self.n2s:
            return False
        if v in self.cminus[u]:
            return False
        return v in self.cplus[u] or pair_key(self.n2s[u], self.n2s[v]) in self.P

    def neighbors(self, u: int) -> Set[int]:
        """Lemma-1 neighborhood retrieval from (G*, C) in O(deg + |C-(u)|)."""
        res = set(self.cplus[u])
        for sid in self.psn[self.n2s[u]]:
            res |= self.members[sid]
        res.discard(u)
        res -= self.cminus[u]
        return res

    # ----------------------------------------------------------------- moves
    def neighbor_hist(self, y: int) -> Dict[int, int]:
        """h[X] = |N(y) ∩ X| per supernode X (reused across candidate scans)."""
        h: Dict[int, int] = {}
        for w in self.neighbors(y):
            s = self.n2s[w]
            h[s] = h.get(s, 0) + 1
        return h

    def _pair_updates(self, y: int, target: int,
                      h: Optional[Dict[int, int]] = None,
                      ) -> Dict[Pair, Tuple[int, int, int, int]]:
        """Per-pair (E_old, T_old, E_new, T_new) induced by moving y -> target.

        ``target`` may be a not-yet-existing sid (escape to fresh singleton),
        signalled by target not in ``self.members``.
        """
        a = self.n2s[y]
        sa = len(self.members[a])
        sb = len(self.members.get(target, ())) if target in self.members else 0
        if h is None:
            h = self.neighbor_hist(y)
        sizes: Dict[int, int] = {}

        def size(x: int) -> int:
            if x == a or x == target:
                raise AssertionError("use explicit sa/sb")
            return len(self.members[x])

        out: Dict[Pair, Tuple[int, int, int, int]] = {}
        others = (set(self.sn.get(a, ())) | set(self.sn.get(target, ())) |
                  set(h)) - {a, target}
        for x in others:
            sx = size(x)
            e_ax = self._count(a, x)
            out[pair_key(a, x)] = (e_ax, sa * sx, e_ax - h.get(x, 0), (sa - 1) * sx)
            e_bx = self._count(target, x) if target in self.members else 0
            out[pair_key(target, x)] = (e_bx, sb * sx, e_bx + h.get(x, 0), (sb + 1) * sx)
        e_aa = self._count(a, a)
        out[(a, a)] = (e_aa, t_count(sa, sa, True),
                       e_aa - h.get(a, 0), t_count(sa - 1, sa - 1, True))
        e_bb = self._count(target, target) if target in self.members else 0
        out[(target, target)] = (e_bb, t_count(sb, sb, True),
                                 e_bb + h.get(target, 0), t_count(sb + 1, sb + 1, True))
        e_ab = self._count(a, target) if target in self.members else 0
        out[pair_key(a, target)] = (e_ab, sa * sb,
                                    e_ab - h.get(target, 0) + h.get(a, 0),
                                    (sa - 1) * (sb + 1))
        return out

    def delta_phi(self, y: int, target: int,
                  h: Optional[Dict[int, int]] = None) -> int:
        """Closed-form change in phi if node y moved into supernode ``target``.

        This is the paper's "computing savings in the objective" step
        (Sect. 3.6.3): only pairs touching SN(S_y) ∪ SN(S_z) matter.
        Pass a precomputed ``neighbor_hist(y)`` when scanning many candidates.
        """
        if target in self.members and self.n2s[y] == target:
            return 0
        d = 0
        for (e0, t0, e1, t1) in self._pair_updates(y, target, h).values():
            d += encoding_cost(e1, t1) - encoding_cost(e0, t0)
        return d

    def new_sid(self) -> int:
        sid = self._next_sid
        self._next_sid += 1
        return sid

    def move(self, y: int, target: int) -> None:
        """Unconditionally move y into supernode ``target`` (created if new)."""
        a = self.n2s[y]
        if target == a:
            return
        if target not in self.members:
            self.members[target] = set()
            self.sn[target] = set()
            self.psn[target] = set()
            self._next_sid = max(self._next_sid, target + 1)
        nbrs = sorted(self.neighbors(y))
        # 1. detach y's edges from the encoding (degree unchanged).
        for w in nbrs:
            self._remove_edge_encoding(y, w)
        # 1b. y leaves the scope of A's superedges: after the detach, y's
        # C- entries are exactly the potential pairs covered by P at A —
        # they stop existing once y departs (phi is count-derived; the
        # matching cost change is applied in step 3's re-costing).
        for q in list(self.cminus[y]):
            self.cminus[q].discard(y)
        self.cminus[y].clear()
        # 2. membership switch.
        self.members[a].remove(y)
        self.members[target].add(y)
        self.n2s[y] = target
        # 2b. y enters the scope of B's superedges: y currently has no
        # encoded edges, so every potential pair covered by a superedge of
        # B is a non-edge and must appear in C- until step 5 re-attaches.
        for x in list(self.psn.get(target, ())):
            for q in self.members[x]:
                if q != y:
                    self.cminus[y].add(q)
                    self.cminus[q].add(y)
        # 3. re-cost every pair of A and B: |T| changed with the sizes.
        touched = set()
        for x in list(self.sn.get(a, ())) + [a]:
            touched.add(pair_key(a, x))
        for x in list(self.sn.get(target, ())) + [target]:
            touched.add(pair_key(target, x))
        for (p, q) in touched:
            e = self._count(p, q)
            if e <= 0:
                continue
            # phi was accounted with the OLD T; recompute with new sizes.
            # Note: old T differs only for pairs involving a or target.
            so_p = len(self.members[p]) + (1 if p == a else 0) - (1 if p == target else 0)
            so_q = len(self.members[q]) + (1 if q == a else 0) - (1 if q == target else 0)
            t_old = t_count(so_p, so_q, p == q)
            t_new = self._t(p, q)
            self.phi += encoding_cost(e, t_new) - encoding_cost(e, t_old)
            self._reencode(p, q)
        # 4. drop A if emptied (all its counts are 0: y was its only member).
        if not self.members[a]:
            assert not self.sn[a], "empty supernode still has edge counts"
            del self.members[a]
            del self.sn[a]
            del self.psn[a]
        # 5. re-attach y's edges under the new membership.
        for w in nbrs:
            self._add_edge_encoding(y, w)

    # ------------------------------------------------------------- materialize
    def materialize(self) -> SummaryOutput:
        cp = set()
        cm = set()
        for u, s in self.cplus.items():
            for v in s:
                cp.add(pair_key(u, v))
        for u, s in self.cminus.items():
            for v in s:
                cm.add(pair_key(u, v))
        return SummaryOutput(
            supernodes={sid: set(m) for sid, m in self.members.items()},
            superedges=set(self.P),
            c_plus=cp,
            c_minus=cm,
        )

    def phi_recomputed(self) -> int:
        """Independent phi from the E_AB counts (tests cross-check)."""
        tot = 0
        for (a, b), e in self.eab.items():
            tot += encoding_cost(e, self._t(a, b))
        return tot

    def compression_ratio(self) -> float:
        """(|P| + |C+| + |C-|) / |E|, the paper's Eq. (3)."""
        if self.num_edges == 0:
            return 0.0
        return self.phi / self.num_edges

    def representation_size(self) -> int:
        """|V| + |P| + |C+| + |C-| (Thm. 4 memory measure)."""
        return len(self.n2s) + self.phi
