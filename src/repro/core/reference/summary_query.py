"""Host reference for the online query path (the differential oracle).

Answers ``neighbors``/``degree``/``has_edge`` from a materialized
:class:`SummaryOutput` (or union-of-parts :class:`ShardedSummaryOutput`)
by walking the output representation itself — membership lookup,
superedge scan, correction patch-up (Lemma 1) — and NEVER by
``decode_edges()``.  Tests triangulate three independent answers per
query: this oracle over the materialized summary, the device kernels in
:mod:`repro.serve.query` over live engine state, and the edge set from
``decode_edges()``; all three must agree exactly.
"""
from __future__ import annotations

from typing import Dict, List, Set, Tuple

from repro.core.summary import (Pair, ShardedSummaryOutput, SummaryOutput,
                                pair_key)


class _Part:
    """Lemma-1 indexes for one summary part (one shard's output)."""

    def __init__(self, out: SummaryOutput) -> None:
        self.members: Dict[int, Set[int]] = {
            sid: set(mem) for sid, mem in out.supernodes.items()}
        self.node2sid: Dict[int, int] = {}
        for sid, mem in self.members.items():
            for u in mem:
                self.node2sid[u] = sid
        self.psn: Dict[int, Set[int]] = {}   # sid -> P-neighbor sids
        for (a, b) in out.superedges:
            self.psn.setdefault(a, set()).add(b)
            self.psn.setdefault(b, set()).add(a)
        self.cplus: Dict[int, Set[int]] = {}
        for (u, v) in out.c_plus:
            self.cplus.setdefault(u, set()).add(v)
            self.cplus.setdefault(v, set()).add(u)
        self.cminus: Dict[int, Set[int]] = {}
        for (u, v) in out.c_minus:
            self.cminus.setdefault(u, set()).add(v)
            self.cminus.setdefault(v, set()).add(u)
        self.c_plus_pairs: Set[Pair] = {pair_key(u, v) for (u, v) in out.c_plus}
        self.c_minus_pairs: Set[Pair] = {pair_key(u, v)
                                         for (u, v) in out.c_minus}
        self.superedges: Set[Pair] = set(out.superedges)

    def neighbors(self, u: int) -> Set[int]:
        """N(u) = (members of P-neighbors of S_u  \\  C-(u)) ∪ C+(u)."""
        res: Set[int] = set(self.cplus.get(u, ()))
        for sid in self.psn.get(self.node2sid[u], ()):
            res |= self.members[sid]
        res.discard(u)
        res -= self.cminus.get(u, set())
        return res

    def has_edge(self, u: int, v: int) -> bool:
        p = pair_key(u, v)
        if p in self.c_minus_pairs:
            return False
        if p in self.c_plus_pairs:
            return True
        if u not in self.node2sid or v not in self.node2sid:
            return False
        return pair_key(self.node2sid[u], self.node2sid[v]) in self.superedges


class SummaryQueryOracle:
    """Query reference over a materialized (possibly sharded) summary.

    A sharded output is a union of parts over disjoint edge partitions, so
    per-part answers merge by union (neighbors) / any (has_edge); a label
    present in no part raises ``LookupError`` — the same contract the
    device views pin.
    """

    def __init__(self, out) -> None:
        shards = out.shards if isinstance(out, ShardedSummaryOutput) else [out]
        self._parts: List[_Part] = [_Part(s) for s in shards]

    def _parts_of(self, u) -> List[_Part]:
        parts = [p for p in self._parts if u in p.node2sid]
        if not parts:
            raise LookupError(f"query: label {u!r} is in no summary part")
        return parts

    def neighbors(self, u) -> Set[int]:
        res: Set[int] = set()
        for p in self._parts_of(u):
            res |= p.neighbors(u)
        return res

    def degree(self, u) -> int:
        return len(self.neighbors(u))

    def has_edge(self, u, v) -> bool:
        self._parts_of(u)
        parts_v = self._parts_of(v)
        if u == v:
            return False
        return any(p.has_edge(u, v) for p in parts_v)
