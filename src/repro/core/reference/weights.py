"""Host mirror of the engine's hashed node weights (weighted objective).

``repro.core.engine.ops.node_weight`` derives ``w(u) = 1 +
(splitmix32(u + 0x5EED * GOLDEN) % weight_levels)`` on device; this module
reproduces it bit-exactly in numpy uint32 arithmetic so host references
and audits can weigh the same node identically.  Keep the two in sync.

Note the engine hashes DENSE engine ids, not caller labels: a
label-space reference must map labels through the front-end's intern
order (``BatchedSummarizer._ids``) before calling ``host_node_weight``
when comparing against device state.
"""
from __future__ import annotations

import numpy as np

_GOLDEN = np.uint32(0x9E3779B9)
_SEED_CTR = np.uint32(0x5EED)


def _splitmix32(x: np.uint32) -> np.uint32:
    with np.errstate(over="ignore"):
        x = (x ^ (x >> np.uint32(16))) * np.uint32(0x21F0AAAD)
        x = (x ^ (x >> np.uint32(15))) * np.uint32(0x735A2D97)
        return x ^ (x >> np.uint32(15))


def host_node_weight(u: int, weight_levels: int) -> int:
    """w(u) for an engine-id (or any int-keyed) node; 1 when levels <= 1."""
    if weight_levels <= 1:
        return 1
    with np.errstate(over="ignore"):
        x = np.uint32(np.int64(u) & 0xFFFFFFFF) + _SEED_CTR * _GOLDEN
    h = _splitmix32(x)
    return 1 + int(h % np.uint32(weight_levels))
