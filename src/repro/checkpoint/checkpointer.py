"""Fault-tolerant checkpointing: atomic, durable, resumable, mesh-elastic.

* atomic: write to ``<dir>.tmp`` then ``os.replace`` (a crashed writer never
  corrupts the last good step);
* durable: every staged file is fsynced, and so are the staging directory
  and the parent directory around the ``os.replace`` — the rename is not
  just atomic against a crashed *writer* but persistent across power loss
  (an un-fsynced rename can legally vanish on journal replay);
* verified: ``meta.json`` carries a sha256 per payload file, so a torn or
  bit-rotted checkpoint is *detected* at restore time (``verify``) instead
  of loading garbage — callers fall back to the previous step
  (``latest_valid_step`` / ``valid_steps``);
* resumable: latest-step discovery + data-cursor restore;
* elastic: ``restore`` re-device_puts every leaf under the *current* mesh's
  shardings, so a job can come back on a different topology (node failures,
  pod resize) — the "elastic scaling" leg of the fault-tolerance story.

Tree paths are percent-encoded per component before joining with ``/``, so
``("a/b",)`` and ``("a", "b")`` can never alias one another in the archive
(the un-escaped join used to collide them).
"""
from __future__ import annotations

import hashlib
import json
import os
import shutil
from typing import Any, Dict, List, Optional
from urllib.parse import quote

import jax
import numpy as np

CKPT_FORMAT_VERSION = 2   # bumped when the on-disk layout changes


def _path_key(path) -> str:
    """Collision-proof archive key for one tree path.

    Each component is percent-encoded (``/`` and ``%`` escaped) before the
    ``/`` join, so distinct paths always produce distinct keys — a raw
    join would alias ``("a/b",)`` with ``("a", "b")``.
    """
    return "/".join(
        quote(str(getattr(p, "key", getattr(p, "idx", p))), safe="")
        for p in path)


def _flatten(tree) -> Dict[str, Any]:
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    return {_path_key(path): leaf for path, leaf in flat}


def _sha256(path: str) -> str:
    h = hashlib.sha256()
    with open(path, "rb") as f:
        for block in iter(lambda: f.read(1 << 20), b""):
            h.update(block)
    return h.hexdigest()


def _fsync_path(path: str) -> None:
    """fsync a file or directory (directory fsync persists the entry list,
    which is what makes a rename durable on power loss)."""
    fd = os.open(path, os.O_RDONLY)
    try:
        os.fsync(fd)
    finally:
        os.close(fd)


def _write_file(path: str, data: bytes) -> None:
    with open(path, "wb") as f:
        f.write(data)
        f.flush()
        os.fsync(f.fileno())


def save(ckpt_dir: str, step: int, tree, extra: Optional[dict] = None,
         blobs: Optional[Dict[str, bytes]] = None) -> str:
    """Write one atomic, durable checkpoint at ``<ckpt_dir>/step_<n>``.

    ``blobs`` are opaque byte payloads stored alongside the array archive
    (host-side closures — label maps, cursors — that are not jax trees);
    each is checksummed in ``meta`` exactly like ``arrays.npz`` and read
    back with :func:`load_blob`.
    """
    os.makedirs(ckpt_dir, exist_ok=True)
    final = os.path.join(ckpt_dir, f"step_{step:08d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp, exist_ok=True)
    flat = _flatten(tree)
    arrays = {k: np.asarray(v) for k, v in flat.items()}
    np.savez(os.path.join(tmp, "arrays.npz"), **arrays)
    _fsync_path(os.path.join(tmp, "arrays.npz"))
    checksums = {"arrays.npz": _sha256(os.path.join(tmp, "arrays.npz"))}
    for name, data in (blobs or {}).items():
        assert name not in ("arrays.npz", "meta.json"), name
        _write_file(os.path.join(tmp, name), data)
        checksums[name] = _sha256(os.path.join(tmp, name))
    meta = {"step": step, "keys": sorted(arrays),
            "format_version": CKPT_FORMAT_VERSION,
            "checksums": checksums,
            "extra": extra or {}}
    _write_file(os.path.join(tmp, "meta.json"),
                json.dumps(meta).encode("utf-8"))
    _fsync_path(tmp)                       # staged entries are on disk
    if os.path.exists(final):
        shutil.rmtree(final)
    os.replace(tmp, final)
    _fsync_path(ckpt_dir)                  # the rename itself is durable
    return final


def checkpoint_steps(ckpt_dir: str) -> List[int]:
    """All step numbers with a (not necessarily valid) final directory."""
    if not os.path.isdir(ckpt_dir):
        return []
    return sorted(int(d.split("_")[1]) for d in os.listdir(ckpt_dir)
                  if d.startswith("step_") and not d.endswith(".tmp"))


def latest_step(ckpt_dir: str) -> Optional[int]:
    steps = checkpoint_steps(ckpt_dir)
    return max(steps) if steps else None


def verify(ckpt_dir: str, step: int) -> bool:
    """True iff the checkpoint's files are present and match their
    recorded sha256 checksums (torn writes and bit rot are *detected*,
    never silently restored).  Pre-checksum checkpoints
    (``format_version`` < 2) verify on file presence only.
    """
    path = os.path.join(ckpt_dir, f"step_{step:08d}")
    try:
        with open(os.path.join(path, "meta.json")) as f:
            meta = json.load(f)
    except (OSError, ValueError):
        return False
    checksums = meta.get("checksums")
    if checksums is None:                  # legacy format: presence only
        return os.path.exists(os.path.join(path, "arrays.npz"))
    try:
        return all(_sha256(os.path.join(path, name)) == want
                   for name, want in checksums.items())
    except OSError:
        return False


def valid_steps(ckpt_dir: str) -> List[int]:
    """Ascending step numbers whose checkpoints pass :func:`verify`."""
    return [s for s in checkpoint_steps(ckpt_dir) if verify(ckpt_dir, s)]


def latest_valid_step(ckpt_dir: str) -> Optional[int]:
    steps = valid_steps(ckpt_dir)
    return max(steps) if steps else None


def delete_step(ckpt_dir: str, step: int) -> None:
    """Remove one checkpoint directory (retention policy helper)."""
    path = os.path.join(ckpt_dir, f"step_{step:08d}")
    if os.path.isdir(path):
        shutil.rmtree(path)


def restore(ckpt_dir: str, step: int, like, shardings=None):
    """Restore into the structure of ``like``; reshard under ``shardings``.

    ``shardings`` may target a different mesh than the one that saved —
    leaves are device_put with the new sharding (elastic restart).
    """
    path = os.path.join(ckpt_dir, f"step_{step:08d}")
    data = np.load(os.path.join(path, "arrays.npz"))
    flat, treedef = jax.tree_util.tree_flatten_with_path(like)
    shard_flat = (jax.tree_util.tree_leaves(shardings)
                  if shardings is not None else [None] * len(flat))
    leaves = []
    for (p, leaf), sh in zip(flat, shard_flat):
        key = _path_key(p)
        arr = data[key]
        assert arr.shape == tuple(leaf.shape), f"shape mismatch at {key}"
        if sh is not None:
            leaves.append(jax.device_put(arr, sh))
        else:
            leaves.append(jax.numpy.asarray(arr, dtype=leaf.dtype))
    return jax.tree_util.tree_unflatten(treedef, leaves)


def load_meta(ckpt_dir: str, step: int) -> dict:
    path = os.path.join(ckpt_dir, f"step_{step:08d}", "meta.json")
    with open(path) as f:
        return json.load(f)


def load_blob(ckpt_dir: str, step: int, name: str) -> bytes:
    """Read back one named blob written by :func:`save`."""
    path = os.path.join(ckpt_dir, f"step_{step:08d}", name)
    with open(path, "rb") as f:
        return f.read()
