"""Fault-tolerant checkpointing: atomic, resumable, mesh-elastic.

* atomic: write to ``<dir>.tmp`` then ``os.replace`` (a crashed writer never
  corrupts the last good step);
* resumable: latest-step discovery + data-cursor restore;
* elastic: ``restore`` re-device_puts every leaf under the *current* mesh's
  shardings, so a job can come back on a different topology (node failures,
  pod resize) — the "elastic scaling" leg of the fault-tolerance story.
"""
from __future__ import annotations

import json
import os
import shutil
from typing import Any, Dict, Optional

import jax
import numpy as np


def _flatten(tree) -> Dict[str, Any]:
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    out = {}
    for path, leaf in flat:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        out[key] = leaf
    return out


def save(ckpt_dir: str, step: int, tree, extra: Optional[dict] = None) -> str:
    """Write one atomic checkpoint at ``<ckpt_dir>/step_<n>``."""
    final = os.path.join(ckpt_dir, f"step_{step:08d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp, exist_ok=True)
    flat = _flatten(tree)
    arrays = {k: np.asarray(v) for k, v in flat.items()}
    np.savez(os.path.join(tmp, "arrays.npz"), **arrays)
    meta = {"step": step, "keys": sorted(arrays),
            "extra": extra or {}}
    with open(os.path.join(tmp, "meta.json"), "w") as f:
        json.dump(meta, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.replace(tmp, final)
    return final


def latest_step(ckpt_dir: str) -> Optional[int]:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = [int(d.split("_")[1]) for d in os.listdir(ckpt_dir)
             if d.startswith("step_") and not d.endswith(".tmp")]
    return max(steps) if steps else None


def restore(ckpt_dir: str, step: int, like, shardings=None):
    """Restore into the structure of ``like``; reshard under ``shardings``.

    ``shardings`` may target a different mesh than the one that saved —
    leaves are device_put with the new sharding (elastic restart).
    """
    path = os.path.join(ckpt_dir, f"step_{step:08d}")
    data = np.load(os.path.join(path, "arrays.npz"))
    flat, treedef = jax.tree_util.tree_flatten_with_path(like)
    shard_flat = (jax.tree_util.tree_leaves(shardings)
                  if shardings is not None else [None] * len(flat))
    leaves = []
    for (p, leaf), sh in zip(flat, shard_flat):
        key = "/".join(str(getattr(q, "key", getattr(q, "idx", q))) for q in p)
        arr = data[key]
        assert arr.shape == tuple(leaf.shape), f"shape mismatch at {key}"
        if sh is not None:
            leaves.append(jax.device_put(arr, sh))
        else:
            leaves.append(jax.numpy.asarray(arr, dtype=leaf.dtype))
    return jax.tree_util.tree_unflatten(treedef, leaves)


def load_meta(ckpt_dir: str, step: int) -> dict:
    path = os.path.join(ckpt_dir, f"step_{step:08d}", "meta.json")
    with open(path) as f:
        return json.load(f)
