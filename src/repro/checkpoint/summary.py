"""Crash-consistent checkpoint/restore/recover for the summarizer tiers.

The recovery contract (held to the standing differential bar's bitwise
standard by ``tests/test_recovery.py``):

    a summarizer killed at ANY chunk boundary and recovered is
    leaf-bitwise equal — EngineState + InternState + telemetry — to the
    run that was never interrupted, and its query answers are identical.

Two mechanisms compose to get there:

* **Epoch checkpoints** — ``flush()`` defines consistent epochs (every
  dispatched chunk fully applied, nothing in flight), and ``save()``
  snapshots the full *recovery closure* at one: the engine state tree
  (``EngineState`` per shard — PRNG position included, ``step_no`` is the
  stream cursor of the trial PRNG), the router's ``InternState``
  (``h2l``/``l2h``), the host-side label closure (hash → label map with
  its lazy buffer folded, or the batched tier's ``_ids``/``_rev``),
  router telemetry, the flush-epoch/journal-seq counters and the stream
  cursor — through the atomic+durable+checksummed
  :mod:`repro.checkpoint.checkpointer`.
* **Chunk journal** — every chunk is durably appended to a write-ahead
  :class:`~repro.checkpoint.journal.ChunkJournal` *before* dispatch, and
  the journal is compacted when a checkpoint lands.  Recovery restores
  the newest checkpoint that passes its checksums and deterministically
  replays the journal tail; chunk boundaries fully determine padding and
  the engine-round/PRNG schedule, so the replay is bitwise.

The checkpoint **manifest** records the config identity the closure was
taken under; :func:`restore_summarizer` refuses a restore whose pinned
manifest entries (engine config incl. the policy triple, tier,
``n_shards``, ``router_chunk``, drain geometry) differ from the live
summarizer — a mismatched restore would not crash, it would silently
break bitwise replay, which is worse.  Execution *variants* that are
leaf-bitwise state-identical by the standing differential bar —
``replica_exec``, ``trial_backend``, ``routing``, mesh topology — are
recorded informationally but NOT pinned: a checkpoint taken on an
8-device mesh restores onto 1 device (same ``n_shards``; the next
dispatch reshards under the live mesh), which is the elastic leg.

Retention: the newest :data:`KEEP_EPOCHS` checkpoints are kept and the
journal is compacted to the *oldest* retained checkpoint's sequence
number — so when the newest checkpoint is later found corrupted
(checksum), recovery falls back one epoch and re-earns the present from
the journal instead of loading garbage.
"""
from __future__ import annotations

import os
import pickle
from typing import Optional

from repro.checkpoint import checkpointer
from repro.checkpoint.journal import ChunkJournal

CKPT_CLOSURE_VERSION = 1
KEEP_EPOCHS = 2     # checkpoint fallback depth (journal covers the span)


def journal_path(ckpt_dir: str) -> str:
    return os.path.join(ckpt_dir, "journal.bin")


class ConfigMismatchError(ValueError):
    """The live summarizer's pinned config differs from the checkpoint's."""


def _check_manifest(summ, extra: dict) -> None:
    want = summ._ckpt_manifest()
    saved = extra.get("manifest", {})
    diffs = [f"{key}: checkpoint={saved.get(key)!r} != live={want.get(key)!r}"
             for key in summ._ckpt_pins() if saved.get(key) != want.get(key)]
    if diffs:
        raise ConfigMismatchError(
            "checkpoint/config mismatch — restoring would silently break "
            "the bitwise replay contract:\n  " + "\n  ".join(diffs))


def save_summarizer(summ, ckpt_dir: str) -> str:
    """Write one epoch checkpoint of ``summ``'s recovery closure.

    Flushes the dispatch pipeline first (the epoch must be consistent),
    snapshots tree + host closure + manifest, applies retention, and
    compacts the journal to the oldest retained checkpoint's sequence.
    The state fetch (``np.asarray`` inside the checkpointer) blocks until
    in-flight dispatches complete, so on buffer-donating backends the
    read happens strictly before any later step could donate the buffers
    (docs/KNOWN_ISSUES.md).
    """
    flush = getattr(summ, "flush", None)
    if flush is not None:
        flush()
    epoch = int(summ.flush_epoch)
    extra = {"closure_version": CKPT_CLOSURE_VERSION,
             "manifest": summ._ckpt_manifest(),
             "epoch": epoch,
             "journal_seq": int(summ._journal_seq),
             "cursor": int(summ._cursor)}
    blob = pickle.dumps(summ._ckpt_host(),
                        protocol=pickle.HIGHEST_PROTOCOL)
    path = checkpointer.save(ckpt_dir, epoch, summ._ckpt_tree(),
                             extra=extra, blobs={"host.pkl": blob})
    for s in checkpointer.checkpoint_steps(ckpt_dir)[:-KEEP_EPOCHS]:
        checkpointer.delete_step(ckpt_dir, s)
    # journal compaction: keep every record the oldest retained checkpoint
    # might still need, so a corrupt newest epoch can fall back and replay
    keep_seq = None
    for s in checkpointer.checkpoint_steps(ckpt_dir):
        try:
            e = checkpointer.load_meta(ckpt_dir, s).get("extra", {})
            keep_seq = min(int(e["journal_seq"]),
                           keep_seq if keep_seq is not None else 1 << 62)
        except (OSError, ValueError, KeyError):
            continue
    if keep_seq is not None and os.path.exists(journal_path(ckpt_dir)):
        ChunkJournal(journal_path(ckpt_dir)).truncate(keep_from_seq=keep_seq)
    return path


def restore_summarizer(summ, ckpt_dir: str,
                       step: Optional[int] = None) -> dict:
    """Restore the newest verifiable checkpoint (or ``step``) into ``summ``.

    Torn or corrupted checkpoints (missing files, checksum mismatch,
    unparseable meta) are *rejected* and the previous retained epoch is
    tried instead; a pinned-manifest mismatch raises
    :class:`ConfigMismatchError` immediately (it is a caller bug, not a
    disk fault).  Raises ``FileNotFoundError`` when nothing restorable
    exists.
    """
    steps = checkpointer.checkpoint_steps(ckpt_dir)
    candidates = [step] if step is not None else sorted(steps, reverse=True)
    failures = []
    for s in candidates:
        if not checkpointer.verify(ckpt_dir, s):
            failures.append(
                f"step {s}: integrity check failed (torn or corrupt)")
            continue
        extra = checkpointer.load_meta(ckpt_dir, s).get("extra", {})
        _check_manifest(summ, extra)
        tree = checkpointer.restore(ckpt_dir, s, like=summ._ckpt_tree())
        host = pickle.loads(checkpointer.load_blob(ckpt_dir, s, "host.pkl"))
        summ._ckpt_apply(tree, host, extra)
        return dict(step=s, epoch=int(extra["epoch"]),
                    journal_seq=int(extra["journal_seq"]),
                    cursor=int(extra["cursor"]), rejected=failures)
    raise FileNotFoundError(
        f"no restorable checkpoint under {ckpt_dir!r}"
        + (f" — rejected: {'; '.join(failures)}" if failures else ""))


def recover_summarizer(summ, ckpt_dir: str) -> dict:
    """Full crash recovery: restore the last valid epoch, then replay the
    journal tail deterministically.

    Returns a dict with the restored ``epoch``, the number of
    ``replayed_chunks`` and the post-replay stream ``cursor`` — the
    caller resumes feeding the stream from ``cursor``.  A directory with
    no checkpoint at all recovers from scratch via the journal alone
    (a crash before the first checkpoint); a directory whose checkpoints
    are ALL corrupt raises — the journal has been compacted past the
    origin, so a silent from-scratch replay would be wrong.
    """
    try:
        info = restore_summarizer(summ, ckpt_dir)
        from_seq = info["journal_seq"]
    except FileNotFoundError:
        if checkpointer.checkpoint_steps(ckpt_dir):
            raise
        info = dict(step=None, epoch=0, journal_seq=0,
                    cursor=int(summ._cursor), rejected=[])
        from_seq = 0
    summ._recovered = True
    records = ChunkJournal(journal_path(ckpt_dir)).replay(from_seq)
    for _seq, changes in records:
        summ._replay_chunk(changes)
    info["replayed_chunks"] = len(records)
    info["cursor"] = int(summ._cursor)
    return info
