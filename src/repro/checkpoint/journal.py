"""Write-ahead chunk journal for crash-consistent streaming recovery.

The summarizers checkpoint at *epoch* granularity (one epoch per dispatched
chunk), but checkpointing every chunk would serialize the whole engine
state onto the host at stream rate.  The journal closes the gap: every
chunk of caller-label changes is appended here — framed, checksummed and
fsynced — **before** it is dispatched to the engine, and the file is
compacted when an epoch checkpoint lands.  Recovery is then

    restore last valid epoch E  +  replay journal records with seq >= E

and, because chunk boundaries fully determine the engine-round/PRNG
schedule, the replayed run is leaf-bitwise equal to the uninterrupted one.

Frame format (little-endian), one record per journaled chunk::

    magic   4 bytes   b"JRN1"
    seq     8 bytes   chunk sequence number == flush_epoch the chunk enters
    length  4 bytes   payload byte length
    crc32   4 bytes   zlib.crc32(payload)
    payload           pickled list of (u, v, is_insert) caller-label changes

A crash can only tear the *tail* record (appends are sequential and each
append is fsynced before the chunk dispatches); :meth:`scan` stops at the
first frame that fails magic/length/CRC validation and reports it as a
torn tail rather than an error.  Duplicated records (a crash between the
append and the seq-counter advance, or an injected fault) are deduped by
sequence number at replay; a *gap* in the sequence means lost acknowledged
writes and is a hard error — replaying across it would silently diverge.

Compaction (:meth:`truncate`) rewrites the file atomically keeping only
records with ``seq >= keep_from_seq``.  The summarizers keep one retained
epoch of history (``keep_from_seq`` = previous checkpoint's epoch), so a
checkpoint whose arrays later fail their checksum can still fall back to
the previous epoch and re-earn the present via replay.
"""
from __future__ import annotations

import os
import pickle
import struct
import zlib
from typing import Iterable, List, Tuple

_MAGIC = b"JRN1"
_HEADER = struct.Struct("<4sQII")   # magic, seq, payload length, crc32


class ChunkJournal:
    """Append-only, fsynced, framed journal of dispatched chunks."""

    def __init__(self, path: str):
        self.path = path
        os.makedirs(os.path.dirname(path) or ".", exist_ok=True)

    # -- write side ------------------------------------------------------

    def append(self, seq: int, changes: Iterable[Tuple]) -> None:
        """Durably append one chunk *before* it is dispatched.

        Returns only once the record is on disk (fsync): if the process
        dies any time after dispatch, the chunk is replayable.
        """
        payload = pickle.dumps(list(changes),
                               protocol=pickle.HIGHEST_PROTOCOL)
        record = _HEADER.pack(_MAGIC, seq, len(payload),
                              zlib.crc32(payload)) + payload
        with open(self.path, "ab") as f:
            f.write(record)
            f.flush()
            os.fsync(f.fileno())

    def truncate(self, keep_from_seq: int = 0) -> None:
        """Atomically compact, keeping records with ``seq >= keep_from_seq``.

        Crash-safe: the new file is staged, fsynced and ``os.replace``d, so
        a reader sees either the old journal (stale records are filtered at
        replay) or the compacted one — never a half-rewritten file.
        """
        kept, _ = self.scan()
        tmp = self.path + ".tmp"
        with open(tmp, "wb") as f:
            for seq, changes in kept:
                if seq < keep_from_seq:
                    continue
                payload = pickle.dumps(changes,
                                       protocol=pickle.HIGHEST_PROTOCOL)
                f.write(_HEADER.pack(_MAGIC, seq, len(payload),
                                     zlib.crc32(payload)) + payload)
            f.flush()
            os.fsync(f.fileno())
        os.replace(tmp, self.path)
        _dir = os.path.dirname(self.path)
        if _dir:
            fd = os.open(_dir, os.O_RDONLY)
            try:
                os.fsync(fd)
            finally:
                os.close(fd)

    def reset(self) -> None:
        """Start a fresh journal (new stream into an old directory)."""
        if os.path.exists(self.path):
            os.remove(self.path)

    # -- read side -------------------------------------------------------

    def scan(self) -> Tuple[List[Tuple[int, list]], bool]:
        """All well-formed records in file order, plus a torn-tail flag.

        Stops at the first frame that fails validation (short header,
        bad magic, short payload, CRC mismatch): everything after a torn
        frame is unreachable garbage by construction, never silently
        reinterpreted as data.
        """
        records: List[Tuple[int, list]] = []
        if not os.path.exists(self.path):
            return records, False
        with open(self.path, "rb") as f:
            data = f.read()
        off = 0
        while off < len(data):
            if off + _HEADER.size > len(data):
                return records, True
            magic, seq, length, crc = _HEADER.unpack_from(data, off)
            if magic != _MAGIC:
                return records, True
            payload = data[off + _HEADER.size: off + _HEADER.size + length]
            if len(payload) < length or zlib.crc32(payload) != crc:
                return records, True
            records.append((seq, pickle.loads(payload)))
            off += _HEADER.size + length
        return records, False

    def replay(self, from_seq: int) -> List[Tuple[int, list]]:
        """Validated, deduplicated tail: records with ``seq >= from_seq``
        in strictly consecutive order.

        * records below ``from_seq`` are pre-checkpoint history → skipped;
        * a record repeating the previous seq is a duplicate → skipped;
        * a seq *jump* means an acknowledged chunk is missing → raise
          (recovering across the hole would be silent divergence).
        """
        records, _torn = self.scan()
        out: List[Tuple[int, list]] = []
        expect = from_seq
        for seq, changes in records:
            if seq < expect:
                continue                    # stale or duplicated record
            if seq > expect:
                raise RuntimeError(
                    f"journal gap: expected chunk seq {expect}, found {seq} "
                    f"in {self.path} — an acknowledged chunk is missing")
            out.append((seq, changes))
            expect += 1
        return out
