"""Fault-tolerance runtime pieces: stragglers, elastic re-mesh, retry loops.

On a real multi-pod fleet these hooks sit in the launcher process:
  * StragglerDetector - robust per-step timing outlier detection; persistent
    stragglers trigger a re-shard plan that excludes the slow host group.
  * plan_elastic_mesh - given surviving device count, pick the largest valid
    (data, model) mesh <= survivors and emit the reshard plan the
    checkpointer executes (restore under new shardings).
  * run_with_retries - step-loop wrapper: on failure, restore latest
    checkpoint and continue (crash-equivalent restart without job loss).
  * run_stream_with_recovery - the streaming-shaped sibling for the
    summarizer tiers: epoch checkpoints + chunk-journal recovery
    (``repro.checkpoint.summary``) with bounded exponential backoff,
    wired into ``launch/stream.py --checkpoint-dir``.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Dict, List, Optional, Sequence, Tuple


@dataclasses.dataclass
class StragglerDetector:
    """Flags hosts whose step time is a robust outlier (median + k*MAD)."""
    k: float = 4.0
    window: int = 32
    min_samples: int = 8
    history: Dict[str, List[float]] = dataclasses.field(default_factory=dict)

    def record(self, host: str, step_time: float) -> None:
        h = self.history.setdefault(host, [])
        h.append(step_time)
        if len(h) > self.window:
            del h[0]

    def stragglers(self) -> List[str]:
        latest = {h: v[-1] for h, v in self.history.items()
                  if len(v) >= self.min_samples}
        if len(latest) < 2:
            return []
        vals = sorted(latest.values())
        med = vals[len(vals) // 2]
        mad = sorted(abs(v - med) for v in vals)[len(vals) // 2]
        thr = med + self.k * max(mad, 0.05 * med, 1e-6)
        return [h for h, v in latest.items() if v > thr]


def plan_elastic_mesh(n_survivors: int, model_parallel: int,
                      ) -> Optional[Tuple[int, int]]:
    """Largest (data, model) mesh that fits the surviving chips.

    Model-parallel degree is preserved (param layout constraint); the data
    axis shrinks to the largest multiple that fits.  Returns None when not
    even one model-parallel group survives.
    """
    if n_survivors < model_parallel:
        return None
    return (n_survivors // model_parallel, model_parallel)


def rebalance_batch(global_batch: int, n_data_shards: int) -> List[int]:
    """Deterministic near-even batch re-slicing after a shrink."""
    base = global_batch // n_data_shards
    extra = global_batch % n_data_shards
    return [base + (1 if i < extra else 0) for i in range(n_data_shards)]


def run_with_retries(step: Callable[[int], None], save_fn: Callable[[int], None],
                     restore_fn: Callable[[], int], n_steps: int,
                     ckpt_every: int = 100, max_failures: int = 3) -> int:
    """Crash-tolerant step loop: failures roll back to the last checkpoint."""
    failures = 0
    i = restore_fn()
    while i < n_steps:
        try:
            step(i)
            i += 1
            if i % ckpt_every == 0:
                save_fn(i)
        except Exception:
            failures += 1
            if failures > max_failures:
                raise
            i = restore_fn()
    return i


def run_stream_with_recovery(make_summarizer: Callable[[], object],
                             stream: Sequence, ckpt_dir: str, *,
                             ckpt_every: int = 16,
                             resume: bool = False,
                             max_failures: int = 3,
                             base_backoff_s: float = 0.05,
                             max_backoff_s: float = 2.0,
                             sleep: Callable[[float], None] = time.sleep):
    """Crash-tolerant streaming driver over a checkpointing summarizer.

    Feeds ``stream`` one dispatch chunk at a time, checkpointing every
    ``ckpt_every`` chunks.  When a chunk fails, the (possibly torn) live
    summarizer is ABANDONED — recovery never trusts in-memory state after
    a fault — and a fresh one from ``make_summarizer()`` restores the
    latest valid epoch, replays the journal tail
    (``repro.checkpoint.summary.recover_summarizer``) and resumes from
    the recovered stream cursor after a bounded exponential backoff.
    ``resume=True`` recovers before the first chunk too (the
    ``launch/stream.py --resume`` path).

    Retries are counted on the summarizer's ``stream_retries`` telemetry
    (reported by ``stats()`` alongside ``router_overflows`` /
    ``router_syncs``); the counter survives summarizer rebuilds but is
    deliberately NOT part of the checkpoint closure — it counts the
    recoveries themselves, so the bitwise recovery bar excludes it.

    Returns the finished summarizer (a final ``save()`` epoch included
    when ``ckpt_every > 0``).
    """
    from repro.ft.inject import drive

    stream = list(stream)
    failures = 0

    def fresh(recover: bool):
        s = make_summarizer()
        if s._ckpt_dir is None:
            s._ckpt_dir = ckpt_dir
        if recover:
            s.recover()
        s.stream_retries = failures
        return s

    summ = fresh(recover=resume)
    while True:
        try:
            drive(summ, stream, ckpt_every=ckpt_every, start=summ.stream_cursor)
            if ckpt_every:
                summ.save()
            return summ
        except Exception:
            failures += 1
            if failures > max_failures:
                raise
            sleep(min(base_backoff_s * (2 ** (failures - 1)), max_backoff_s))
            summ = fresh(recover=True)
