"""Fault injection for the crash-consistency layer.

``tests/test_recovery.py`` drives these helpers to prove the recovery
contract of :mod:`repro.checkpoint.summary`:

* :func:`drive` feeds a stream chunk-at-a-time with periodic epoch
  checkpoints and can raise :class:`SimulatedCrash` at any chunk boundary
  — the in-process equivalent of ``kill -9`` between dispatches (the
  crashed summarizer object is abandoned; recovery always starts from a
  FRESH summarizer plus the on-disk state, exactly like a real restart);
* the ``*_checkpoint`` / ``*_journal`` helpers corrupt the on-disk state
  the way real crashes and bit rot do: torn staging directories left by a
  death mid-``os.replace``, truncated/duplicated journal tails, flipped
  bytes inside ``arrays.npz`` that only a checksum can catch.

Everything here is deliberately host-side file surgery — no engine
internals are touched, so the harness exercises the same recovery path a
production driver (``launch/stream.py --resume``) runs.
"""
from __future__ import annotations

import os
from typing import Optional, Sequence

from repro.checkpoint import checkpointer
from repro.checkpoint.journal import _HEADER, _MAGIC, ChunkJournal
from repro.checkpoint.summary import journal_path


class SimulatedCrash(RuntimeError):
    """Raised by :func:`drive` at an injected kill point."""


def drive(summ, stream: Sequence, *, ckpt_every: int = 0,
          kill_at_chunk: Optional[int] = None, start: int = 0) -> int:
    """Feed ``stream[start:]`` through ``summ`` one dispatch chunk at a
    time, checkpointing every ``ckpt_every`` chunks (0 = never).

    ``kill_at_chunk=k`` raises :class:`SimulatedCrash` at the k-th chunk
    boundary of THIS call (before dispatching chunk k) — k = 0 kills
    before any work, k = #chunks kills after the final dispatch but
    before the driver would naturally finish.  Returns the number of
    chunks dispatched.
    """
    size = summ.dispatch_chunk
    stream = list(stream)
    n = 0
    for off in range(start, len(stream), size):
        if kill_at_chunk is not None and n == kill_at_chunk:
            raise SimulatedCrash(f"injected kill at chunk boundary {n}")
        summ.process(stream[off:off + size])
        n += 1
        if ckpt_every and n % ckpt_every == 0:
            summ.save()
    if kill_at_chunk is not None and n == kill_at_chunk:
        raise SimulatedCrash(f"injected kill at final chunk boundary {n}")
    return n


# --------------------------------------------------------------------------- #
# checkpoint faults
# --------------------------------------------------------------------------- #


def _step_dir(ckpt_dir: str, step: int) -> str:
    return os.path.join(ckpt_dir, f"step_{step:08d}")


def corrupt_checkpoint_arrays(ckpt_dir: str, step: int,
                              offset: int = 256, nbytes: int = 8) -> None:
    """Flip bits inside ``arrays.npz`` — silent corruption that only the
    sha256 in ``meta`` can detect (the file stays a readable npz)."""
    path = os.path.join(_step_dir(ckpt_dir, step), "arrays.npz")
    size = os.path.getsize(path)
    offset = min(offset, max(size - nbytes, 0))
    with open(path, "r+b") as f:
        f.seek(offset)
        chunk = f.read(nbytes)
        f.seek(offset)
        f.write(bytes(b ^ 0xFF for b in chunk))


def drop_checkpoint_file(ckpt_dir: str, step: int,
                         name: str = "arrays.npz") -> None:
    """Remove one payload file — a partially-propagated final directory."""
    os.remove(os.path.join(_step_dir(ckpt_dir, step), name))


def tear_checkpoint_staging(ckpt_dir: str, step: int) -> None:
    """Simulate a crash mid-save, before ``os.replace``: the final
    directory for ``step`` does not exist, only a half-written ``.tmp``
    staging directory (arrays written, no ``meta.json``) is left behind.
    A correct restore must ignore it entirely."""
    import shutil
    final = _step_dir(ckpt_dir, step)
    tmp = final + ".tmp"
    if os.path.isdir(final):   # demote a finished checkpoint to torn state
        shutil.rmtree(tmp, ignore_errors=True)
        os.replace(final, tmp)
        meta = os.path.join(tmp, "meta.json")
        if os.path.exists(meta):
            os.remove(meta)
    else:
        os.makedirs(tmp, exist_ok=True)
        with open(os.path.join(tmp, "arrays.npz"), "wb") as f:
            f.write(b"\x93NUMPY garbage" * 17)


# --------------------------------------------------------------------------- #
# journal faults
# --------------------------------------------------------------------------- #


def truncate_journal_tail(ckpt_dir: str, nbytes: int = 7) -> None:
    """Cut ``nbytes`` off the journal — a torn final append (power loss
    mid-write).  Recovery must keep the valid prefix and stop there."""
    path = journal_path(ckpt_dir)
    size = os.path.getsize(path)
    with open(path, "r+b") as f:
        f.truncate(max(size - nbytes, 0))


def duplicate_journal_tail(ckpt_dir: str) -> None:
    """Re-append the journal's last record verbatim — a crash between the
    durable append and the seq-counter advance.  Replay must dedup it by
    sequence number."""
    path = journal_path(ckpt_dir)
    with open(path, "rb") as f:
        data = f.read()
    off = last = 0
    while off + _HEADER.size <= len(data):
        magic, _seq, length, _crc = _HEADER.unpack_from(data, off)
        if magic != _MAGIC or off + _HEADER.size + length > len(data):
            break
        last, off = off, off + _HEADER.size + length
    with open(path, "ab") as f:
        f.write(data[last:off])
        f.flush()
        os.fsync(f.fileno())


def journal_record_count(ckpt_dir: str) -> int:
    """Well-formed records currently in the journal (fault-free scan)."""
    records, _torn = ChunkJournal(journal_path(ckpt_dir)).scan()
    return len(records)


def latest_checkpoint_step(ckpt_dir: str) -> Optional[int]:
    return checkpointer.latest_step(ckpt_dir)
