"""Online serving layer: read queries answered from the live summary."""
from repro.serve.query import (QueryKernels, ShardedSummaryQuery,
                               SummaryQuery, make_query_kernels,
                               make_sharded_query_kernels)

__all__ = [
    "QueryKernels", "SummaryQuery", "ShardedSummaryQuery",
    "make_query_kernels", "make_sharded_query_kernels",
]
