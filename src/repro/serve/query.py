"""Online query engine over the live compressed summary (no decompression).

The paper's headline property — the summary graph plus corrections *is*
the graph — is served here as a read path: ``neighbors(u)``, ``degree(u)``
and ``has_edge(u, v)`` are answered directly from :class:`EngineState`
arrays, never by ``decode_edges()``.  Every answer walks the encoding the
way Lemma 1 prescribes:

1. **membership lookup** — ``n2s[u]`` resolves u's supernode A (unseen
   nodes are the caller-facing ``LookupError`` contract);
2. **superedge scan** — A's supernode adjacency (``snadj``/``eab``/
   ``ssize``) is scanned under the optimal-encoding rule ``2e > t + 1``,
   yielding the candidate neighbors covered by superedges of A;
3. **correction patch-up** — u's correction store is consulted: pairs in
   C+ mode add their listed edges, pairs in superedge mode subtract the
   C- holes.

The corrections are a *derived* view on device (the engine never
materializes C+/C- arrays — ``adj``/``epos`` is the correction store), so
step 3 reads u's adjacency slot list and classifies each listed edge by
its pair's encoding mode.  The composed answer
``(superedge-candidates ∩ listed) ∪ C+-listed`` therefore cross-checks
``n2s``/``ssize``/``eab``/``snadj`` against ``adj``/``deg`` on every
query — which is exactly what lets tests hold the read path to a
query-vs-decode differential bar: any drift between the summary encoding
and the edge store shows up as a wrong answer, not a hidden invariant.

Everything compiles to batched jit kernels: the per-query scans are
``O(sndeg(A) + deg(u))`` dynamic-trip loops vmapped over the query batch,
and the point probes (``eab``/``epos``) lower through
``ht_lookup_batch``/``ht_find_batch`` under the active trial backend, so
``REPRO_TRIAL_BACKEND=pallas`` serves reads through the same fused probe
kernel the write path uses.

Two host-facing views wrap the kernels:

* :class:`SummaryQuery` — snapshot view over a ``BatchedSummarizer``.
* :class:`ShardedSummaryQuery` — snapshot view over a
  ``ShardedSummarizer``: queries are hash-placed (``labelhash``) and
  fanned out to every shard inside one ``shard_map`` kernel (edge
  partitioning is a vertex cut, so a node's neighborhood may span all
  shards); per-shard answers merge by union (neighbors), sum (degree) or
  any (has_edge — only the ``shard_key`` owner of a pair can hold it).

**Snapshot semantics.**  A view pins the state references that are live
when ``query()`` is called.  Engine dispatch replaces state pytrees
functionally (never in place), so a snapshot is always SOME flushed
epoch's state — on the pipelined sharded path the snapshot intentionally
lags the write head by the one routed-but-not-dispatched chunk, which is
what lets reads run concurrent with an in-flight write chunk without ever
observing a torn intermediate.  ``view.epoch`` records which flush epoch
the answers correspond to.  On buffer-donating backends (non-CPU) the
NEXT engine dispatch invalidates a held snapshot; pass ``copy=True`` or
consume the view before resuming writes (docs/KNOWN_ISSUES.md).

**Policy independence (PR 8).**  The read path takes no
``proposal``/``objective``/``commit`` branches, because per pair the
composed answer algebraically reduces to the LISTED edge set whichever
mode rule classifies the pair (superedge mode: candidates minus the
derived C- holes == candidates ∩ listed; C+ mode: the listed edges
verbatim) — and every policy maintains ``adj``/``epos`` as the exact
live edge set.  The weighted objective's different mode threshold
(``2W > TW + 1`` over weighted masses instead of ``2e > t + 1`` over
counts) therefore cannot change an answer.  This module needs no
per-policy code; the contract is pinned by
``tests/test_differential.py::test_query_vs_decode_under_nondefault_policies``.
"""
from __future__ import annotations

from functools import lru_cache
from typing import List, NamedTuple, Sequence, Set, Tuple

import numpy as np

import jax
import jax.numpy as jnp

from repro.core.engine.hashtable import (ht_find_batch, ht_lookup,
                                         ht_lookup_batch,
                                         resolve_trial_backend,
                                         trial_backend_scope)
from repro.core.engine.ops import t_of
from repro.core.engine.state import EngineState


# --------------------------------------------------------------------------- #
# engine-id query cores (single EngineState, jit/vmap-compatible)
# --------------------------------------------------------------------------- #


def _neighbors_one(st: EngineState, u: jax.Array,
                   ) -> Tuple[jax.Array, jax.Array]:
    """Lemma-1 neighborhood of one engine-id node as a bool[n_cap] mask.

    ``u < 0`` or unseen (``n2s[u] < 0``) lanes answer an all-False mask
    with ``ok=False``.  The scan bounds are the true ``sndeg(A)`` /
    ``deg(u)``, so per-query work matches the paper's retrieval cost; the
    two masks compose as ``(superedge-candidates ∩ listed) ∪ C+-listed``
    which equals N(u) exactly when the summary encoding is consistent
    with the edge store (the query-vs-decode differential bar).
    """
    n_cap = st.n2s.shape[0]
    ok = u >= 0
    uu = jnp.where(ok, u, 0)
    a = st.n2s[uu]
    ok = ok & (a >= 0)
    a0 = jnp.where(ok, a, 0)
    sz_a = st.ssize[a0]

    def pair_is_superedge(b0):
        ca, cb = jnp.minimum(a0, b0), jnp.maximum(a0, b0)
        e = ht_lookup(st.eab, ca, cb)
        t = t_of(sz_a, st.ssize[b0], a0 == b0)
        return 2 * e > t + 1

    # step 2: superedge scan over SN(A) -> candidate supernodes
    def sn_body(i, m):
        b0 = jnp.clip(ht_lookup(st.snadj, a0, i), 0)
        return m.at[b0].set(m[b0] | pair_is_superedge(b0))

    se_sid = jax.lax.fori_loop(0, jnp.where(ok, st.sndeg[a0], 0), sn_body,
                               jnp.zeros((n_cap,), jnp.bool_))
    cand = se_sid[jnp.clip(st.n2s, 0)] & (st.n2s >= 0)

    # step 3: correction patch-up from u's slot list (the derived C store):
    # a listed edge whose pair is in C+ mode is a C+ entry; a candidate
    # pair NOT listed is a C- hole (it drops out of cand & listed)
    def adj_body(i, carry):
        listed, cplus = carry
        w0 = jnp.clip(ht_lookup(st.adj, uu, i), 0)
        se = pair_is_superedge(st.n2s[w0])
        return (listed.at[w0].set(True),
                cplus.at[w0].set(cplus[w0] | ~se))

    listed, cplus = jax.lax.fori_loop(
        0, jnp.where(ok, st.deg[uu], 0), adj_body,
        (jnp.zeros((n_cap,), jnp.bool_), jnp.zeros((n_cap,), jnp.bool_)))

    return ((cand & listed) | cplus) & ok, ok


def _degree_core(st: EngineState, u: jax.Array,
                 ) -> Tuple[jax.Array, jax.Array]:
    """(degree, ok) per query id; 0 / False for invalid or unseen lanes."""
    ok = u >= 0
    uu = jnp.where(ok, u, 0)
    ok = ok & (st.n2s[uu] >= 0)
    return jnp.where(ok, st.deg[uu], 0), ok


def _has_edge_core(st: EngineState, u: jax.Array, v: jax.Array,
                   ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """(present, via_superedge, ok) per query pair, batched probes.

    Membership -> one batched ``eab`` probe decides the pair's encoding
    mode -> one batched ``epos`` probe consults the correction store: in
    C+ mode the edge is present iff listed; in superedge mode it is
    present iff NOT a C- hole — both reduce to the same listed-edge
    probe, so ``via_superedge`` reports which arm answered (the per-query
    cost accounting the utility-variant papers motivate).
    """
    ok = (u >= 0) & (v >= 0) & (u != v)
    uu = jnp.where(ok, u, 0)
    vv = jnp.where(ok, v, 0)
    a, b = st.n2s[uu], st.n2s[vv]
    ok = ok & (a >= 0) & (b >= 0)
    a0 = jnp.where(ok, a, 0)
    b0 = jnp.where(ok, b, 0)
    ca, cb = jnp.minimum(a0, b0), jnp.maximum(a0, b0)
    e = ht_lookup_batch(st.eab, ca, cb)
    t = t_of(st.ssize[a0], st.ssize[b0], a0 == b0)
    se = (2 * e > t + 1) & ok
    _, listed = ht_find_batch(st.epos, uu, vv)
    return listed & ok, se, ok


class QueryKernels(NamedTuple):
    neighbors: object   # (state, u[Q]) -> (mask[Q, n_cap], ok[Q])
    degree: object      # (state, u[Q]) -> (deg[Q], ok[Q])
    has_edge: object    # (state, u[Q], v[Q]) -> (present, via_se, ok)[Q]


@lru_cache(maxsize=None)
def _query_kernels(trial_backend: str) -> QueryKernels:
    def neighbors(st, u):
        with trial_backend_scope(trial_backend):
            return jax.vmap(lambda x: _neighbors_one(st, x))(u)

    def degree(st, u):
        with trial_backend_scope(trial_backend):
            return _degree_core(st, u)

    def has_edge(st, u, v):
        with trial_backend_scope(trial_backend):
            return _has_edge_core(st, u, v)

    # read-only kernels: nothing is donated, so a snapshot can be queried
    # repeatedly without consuming its buffers
    return QueryKernels(neighbors=jax.jit(neighbors),
                        degree=jax.jit(degree),
                        has_edge=jax.jit(has_edge))


def make_query_kernels(trial_backend: str | None = None) -> QueryKernels:
    """Jitted single-engine query kernels under the given probe backend.

    Memoized on the resolved backend; jit handles shape polymorphism, so
    one kernel set serves every config and (padded) query-batch size.
    """
    return _query_kernels(resolve_trial_backend(trial_backend))


# --------------------------------------------------------------------------- #
# sharded fan-out kernels (stacked EngineState + InternState)
# --------------------------------------------------------------------------- #

_SHARDED_CACHE: dict = {}


def _intern_resolve(ist, hi: jax.Array, lo: jax.Array,
                    ) -> Tuple[jax.Array, jax.Array]:
    """Hash words -> (local nid, found) against one shard's intern table.

    The intern keys are full-entropy label hashes, so the batch probe is
    ``prehashed`` (same layout contract as the router's pre-lookup);
    ``hi < 0`` marks padded query lanes.
    """
    valid = hi >= 0
    h1 = jnp.where(valid, hi, 0)
    h2 = jnp.where(valid, lo, 0)
    slot, found = ht_find_batch(ist.h2l, h1, h2, prehashed=True)
    found = found & valid
    return jnp.where(found, ist.h2l.val[slot], -1), found


def make_sharded_query_kernels(cfg, mesh, trial_backend: str | None = None,
                               ) -> QueryKernels:
    """shard_map query kernels over the stacked per-shard states.

    Queries arrive as replicated hash-word arrays; every shard resolves
    them against its own intern table and answers for the nodes it knows
    (vertex-cut fan-out).  Outputs keep the leading shard axis — the host
    view merges them (union / sum / any) — plus per-shard ``found`` flags
    whose across-shard disjunction is the seen-label contract.  Memoized
    on ``(cfg, mesh, trial_backend)`` like the router steps.
    """
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    from repro.dist.router import _state_specs

    trial_backend = resolve_trial_backend(trial_backend)
    key = ("query", cfg, mesh, trial_backend)
    if key in _SHARDED_CACHE:
        return _SHARDED_CACHE[key]
    axis = mesh.axis_names[0]
    est_specs, ist_specs = _state_specs(cfg, axis)

    def nbrs_local(est, ist, hi, lo):
        with trial_backend_scope(trial_backend):
            def per_shard(st, it):
                nid, found = _intern_resolve(it, hi, lo)
                mask, _ = jax.vmap(lambda x: _neighbors_one(st, x))(nid)
                return mask, found
            return jax.vmap(per_shard)(est, ist)

    def deg_local(est, ist, hi, lo):
        with trial_backend_scope(trial_backend):
            def per_shard(st, it):
                nid, found = _intern_resolve(it, hi, lo)
                d, _ = _degree_core(st, nid)
                return d, found
            return jax.vmap(per_shard)(est, ist)

    def he_local(est, ist, uhi, ulo, vhi, vlo):
        with trial_backend_scope(trial_backend):
            def per_shard(st, it):
                nu, fu = _intern_resolve(it, uhi, ulo)
                nv, fv = _intern_resolve(it, vhi, vlo)
                present, se, _ = _has_edge_core(st, nu, nv)
                return present, se, fu, fv
            return jax.vmap(per_shard)(est, ist)

    def wrap(fn, n_q_args, n_out):
        return jax.jit(shard_map(
            fn, mesh=mesh,
            in_specs=(est_specs, ist_specs) + (P(),) * n_q_args,
            out_specs=(P(axis),) * n_out, check_rep=False))

    kernels = QueryKernels(neighbors=wrap(nbrs_local, 2, 2),
                           degree=wrap(deg_local, 2, 2),
                           has_edge=wrap(he_local, 4, 4))
    _SHARDED_CACHE[key] = kernels
    return kernels


# --------------------------------------------------------------------------- #
# host-facing snapshot views
# --------------------------------------------------------------------------- #


def _pad_pow2(a: np.ndarray, fill) -> np.ndarray:
    """Pad a 1-D query array to the next power of two (min 8) so jit
    retraces O(log Q) shapes instead of one per batch size."""
    n = max(8, 1 << (max(len(a), 1) - 1).bit_length())
    if len(a) == n:
        return a
    return np.concatenate([a, np.full(n - len(a), fill, a.dtype)])


class SummaryQuery:
    """Read view over one ``BatchedSummarizer`` snapshot (caller labels).

    Pins the engine state and the interned-label horizon at construction:
    labels streamed after ``query()`` raise ``LookupError`` here even
    though the summarizer has since seen them, and answers keep matching
    the pinned epoch on non-donating backends.
    """

    def __init__(self, summarizer) -> None:
        self._state = summarizer.state
        self._ids = summarizer._ids          # live dict; horizon pins reads
        self._rev = summarizer._rev
        self._n_seen = len(summarizer._rev)
        self._k = make_query_kernels(summarizer.trial_backend)
        self.epoch = summarizer.flush_epoch
        self._summ = summarizer
        self._inc = summarizer._incarnation  # restore fences this view

    # ------------------------------------------------------------- id space
    def _check_pin(self) -> None:
        """A checkpoint ``restore()`` rewinds the summarizer to a different
        epoch lineage and replaces its label maps; a view pinned before the
        restore would resolve labels against state it was never snapshotted
        from.  Fail loudly instead — take a fresh ``query()`` view."""
        if self._summ._incarnation != self._inc:
            raise RuntimeError(
                f"query view pinned at epoch {self.epoch} predates a "
                f"checkpoint restore on this summarizer; take a fresh "
                f"view with .query()")

    def seen_labels(self) -> List[object]:
        """Labels interned at snapshot time, in encounter order."""
        self._check_pin()
        return list(self._rev[:self._n_seen])

    def _nids(self, labels: Sequence[object]) -> np.ndarray:
        self._check_pin()
        out = np.empty(len(labels), np.int32)
        for i, lab in enumerate(labels):
            nid = self._ids.get(lab)
            if nid is None or nid >= self._n_seen:
                raise LookupError(
                    f"query: label {lab!r} has not been streamed "
                    f"(as of epoch {self.epoch})")
            out[i] = nid
        return out

    # -------------------------------------------------------------- queries
    def neighbors_batch(self, labels: Sequence[object]) -> List[Set[object]]:
        u = _pad_pow2(self._nids(labels), -1)
        mask = np.asarray(self._k.neighbors(self._state, u)[0])
        return [{self._rev[w] for w in np.flatnonzero(mask[i])}
                for i in range(len(labels))]

    def neighbors(self, label: object) -> Set[object]:
        return self.neighbors_batch([label])[0]

    def degree_batch(self, labels: Sequence[object]) -> List[int]:
        u = _pad_pow2(self._nids(labels), -1)
        d = np.asarray(self._k.degree(self._state, u)[0])
        return [int(x) for x in d[:len(labels)]]

    def degree(self, label: object) -> int:
        return self.degree_batch([label])[0]

    def has_edge_batch(self, pairs: Sequence[Tuple[object, object]],
                       ) -> List[bool]:
        u = _pad_pow2(self._nids([p[0] for p in pairs]), -1)
        v = _pad_pow2(self._nids([p[1] for p in pairs]), -1)
        present = np.asarray(self._k.has_edge(self._state, u, v)[0])
        return [bool(x) for x in present[:len(pairs)]]

    def has_edge(self, u: object, v: object) -> bool:
        return self.has_edge_batch([(u, v)])[0]


class ShardedSummaryQuery:
    """Read view over one ``ShardedSummarizer`` flush-epoch snapshot.

    Construction performs NO device fetch and does not flush the dispatch
    pipeline: on the pipelined router the snapshot is the last state an
    engine stage produced (``epoch`` chunks applied), so reads proceed
    while the routed-but-undispatched chunk — and any in-flight engine
    work — stays in flight.  The snapshot's own ``n_dropped`` counters
    are checked on the first materialized answer (capacity overflows must
    not serve silently-lossy reads).
    """

    def __init__(self, summarizer, copy: bool = False) -> None:
        est, ist = summarizer.state, summarizer.intern
        if copy:   # survive buffer donation by later writes (non-CPU)
            est = jax.tree.map(jnp.copy, est)
            ist = jax.tree.map(jnp.copy, ist)
        self._est, self._ist = est, ist
        self._summ = summarizer
        self._k = make_sharded_query_kernels(
            summarizer.cfg, summarizer.mesh, summarizer.trial_backend)
        self._rev_cache: dict = {}
        self._intern_host = None
        self.epoch = summarizer.flush_epoch
        self.n_shards = summarizer.n_shards
        self._inc = summarizer._incarnation  # restore fences this view

    # ------------------------------------------------------------- id space
    def _check_pin(self) -> None:
        """Restore fence (see :meth:`SummaryQuery._check_pin`): this view
        resolves nids through the summarizer's live hash -> label map, so a
        checkpoint restore — which replaces that map with a different
        lineage's — must invalidate it loudly."""
        if self._summ._incarnation != self._inc:
            raise RuntimeError(
                f"query view pinned at epoch {self.epoch} predates a "
                f"checkpoint restore on this summarizer; take a fresh "
                f"view with .query()")

    def _hash_words(self, labels: Sequence[object]):
        self._check_pin()
        from repro.dist import labelhash
        hi, lo = labelhash.hash_words(list(labels))
        return _pad_pow2(hi, -1), _pad_pow2(lo, -1)

    def _require_seen(self, labels, found: np.ndarray) -> None:
        seen = found.any(axis=0)
        for i, lab in enumerate(labels):
            if not seen[i]:
                raise LookupError(
                    f"query: label {lab!r} has not been streamed "
                    f"(as of epoch {self.epoch})")

    def _snapshot_intern(self):
        """Host copy of the snapshot's reverse maps (one fetch, memoized);
        also the capacity tripwire for every answer this view serves."""
        if self._intern_host is None:
            l2h, n_nodes, n_dropped = jax.device_get(
                (self._ist.l2h, self._ist.n_nodes, self._ist.n_dropped))
            self._summ._raise_if_dropped(int(np.sum(n_dropped)))
            self._intern_host = (np.asarray(l2h), np.asarray(n_nodes))
        return self._intern_host

    def _rev(self, shard: int) -> List[object]:
        """nid -> caller label for one shard, from the SNAPSHOT intern."""
        if shard not in self._rev_cache:
            from repro.dist import labelhash
            self._check_pin()
            l2h, n_nodes = self._snapshot_intern()
            rows = l2h[shard][:int(n_nodes[shard])]
            self._summ._fold_labels()   # append-only superset map: safe
            h2l = self._summ._h2label
            self._rev_cache[shard] = [
                h2l[int(h)] for h in labelhash.combine(rows[:, 0],
                                                       rows[:, 1])]
        return self._rev_cache[shard]

    def seen_labels(self) -> List[object]:
        """Distinct labels interned in any shard at snapshot time."""
        out, seen = [], set()
        for s in range(self.n_shards):
            for lab in self._rev(s):
                if lab not in seen:
                    seen.add(lab)
                    out.append(lab)
        return out

    # -------------------------------------------------------------- queries
    def neighbors_batch(self, labels: Sequence[object]) -> List[Set[object]]:
        hi, lo = self._hash_words(labels)
        mask, found = self._k.neighbors(self._est, self._ist, hi, lo)
        mask, found = np.asarray(mask), np.asarray(found)
        self._snapshot_intern()
        self._require_seen(labels, found)
        out: List[Set[object]] = []
        for q in range(len(labels)):
            acc: Set[object] = set()
            for s in range(self.n_shards):
                hits = np.flatnonzero(mask[s, q])
                if hits.size:
                    rev = self._rev(s)
                    acc.update(rev[int(w)] for w in hits)
            out.append(acc)
        return out

    def neighbors(self, label: object) -> Set[object]:
        return self.neighbors_batch([label])[0]

    def degree_batch(self, labels: Sequence[object]) -> List[int]:
        hi, lo = self._hash_words(labels)
        d, found = self._k.degree(self._est, self._ist, hi, lo)
        d, found = np.asarray(d), np.asarray(found)
        self._snapshot_intern()
        self._require_seen(labels, found)
        # per-shard edge partitions are disjoint, so degrees add exactly
        return [int(x) for x in d.sum(axis=0)[:len(labels)]]

    def degree(self, label: object) -> int:
        return self.degree_batch([label])[0]

    def has_edge_by_shard(self, pairs: Sequence[Tuple[object, object]],
                          ) -> np.ndarray:
        """bool[n_shards, len(pairs)]: which shard holds each edge.  At
        most one True per column — the pair's ``shard_key`` owner."""
        uh, ul = self._hash_words([p[0] for p in pairs])
        vh, vl = self._hash_words([p[1] for p in pairs])
        present, _, fu, fv = self._k.has_edge(
            self._est, self._ist, uh, ul, vh, vl)
        present, fu, fv = (np.asarray(x) for x in (present, fu, fv))
        self._snapshot_intern()
        self._require_seen([p[0] for p in pairs], fu)
        self._require_seen([p[1] for p in pairs], fv)
        return present[:, :len(pairs)]

    def has_edge_batch(self, pairs: Sequence[Tuple[object, object]],
                       ) -> List[bool]:
        present = self.has_edge_by_shard(pairs)
        return [bool(x) for x in present.any(axis=0)]

    def has_edge(self, u: object, v: object) -> bool:
        return self.has_edge_batch([(u, v)])[0]
