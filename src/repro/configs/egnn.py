"""egnn [gnn]: 4L d_hidden=64 E(n)-equivariant.  [arXiv:2102.09844; paper]"""
from repro.configs.base import ArchSpec, gnn_cells, register
from repro.models.gnn import GNNConfig

ARCH_ID = "egnn"


def full_config() -> GNNConfig:
    return GNNConfig(name=ARCH_ID, arch="egnn", n_layers=4, d_hidden=64,
                     d_in=32, n_classes=8)


def smoke_config() -> GNNConfig:
    return GNNConfig(name=ARCH_ID + "-smoke", arch="egnn", n_layers=2,
                     d_hidden=16, d_in=8, n_classes=4)


SPEC = register(ArchSpec(
    arch_id=ARCH_ID, family="gnn", source="arXiv:2102.09844",
    make_config=full_config, make_smoke_config=smoke_config,
    cells=gnn_cells(needs_coords=True),
    technique_applicable="marginal (molecular graphs, see dimenet note)"))
