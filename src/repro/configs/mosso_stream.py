"""The paper's own workload: batched incremental summarization of a fully
dynamic graph stream (MoSSo, KDD 2020), as a distributable step."""
from repro.configs.base import ArchSpec, ShapeCell, register, sds
from repro.core.engine.state import EngineConfig
import jax.numpy as jnp

ARCH_ID = "mosso-stream"


def full_config() -> EngineConfig:
    return EngineConfig(n_cap=1 << 20, m_cap=1 << 23, d_cap=64, sn_cap=48,
                        c=32, batch=256, escape=0.2)


def smoke_config() -> EngineConfig:
    return EngineConfig(n_cap=512, m_cap=4096, d_cap=32, sn_cap=24,
                        c=8, batch=16, escape=0.3)


def _inputs(cfg):
    b = cfg.batch
    return dict(u=sds((b,), jnp.int32), v=sds((b,), jnp.int32),
                ins=sds((b,), jnp.bool_))


SPEC = register(ArchSpec(
    arch_id=ARCH_ID, family="mosso", source="KDD 2020 (this paper)",
    make_config=full_config, make_smoke_config=smoke_config,
    cells=(ShapeCell(name="stream_batch", kind="stream", inputs=_inputs),),
    technique_applicable="this IS the technique"))
