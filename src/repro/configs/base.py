"""Arch registry: every assigned architecture as a selectable config.

An :class:`ArchSpec` binds (full config, smoke config, per-shape input
specs, step builders).  ``input_specs`` returns ShapeDtypeStructs only — the
dry-run never allocates the full-size tensors.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

F32, I32, BF16, BOOL = jnp.float32, jnp.int32, jnp.bfloat16, jnp.bool_


def sds(shape, dtype):
    return jax.ShapeDtypeStruct(tuple(int(x) for x in shape), dtype)


@dataclasses.dataclass(frozen=True)
class ShapeCell:
    """One (architecture x input-shape) dry-run cell."""
    name: str
    kind: str                      # train | prefill | decode | serve
    inputs: Callable[[Any], Dict[str, jax.ShapeDtypeStruct]]
    note: str = ""
    skip: bool = False             # e.g. long_500k on pure full-attention LMs


@dataclasses.dataclass(frozen=True)
class ArchSpec:
    arch_id: str
    family: str                    # lm | gnn | recsys | mosso
    source: str                    # public-literature citation
    make_config: Callable[[], Any]
    make_smoke_config: Callable[[], Any]
    cells: Tuple[ShapeCell, ...]
    technique_applicable: str = ""  # DESIGN.md §Arch-applicability note

    def cell(self, name: str) -> ShapeCell:
        for c in self.cells:
            if c.name == name:
                return c
        raise KeyError(f"{self.arch_id} has no shape {name}")


REGISTRY: Dict[str, ArchSpec] = {}


def register(spec: ArchSpec) -> ArchSpec:
    REGISTRY[spec.arch_id] = spec
    return spec


# ---------------------------------------------------------------- LM shapes

LM_SHAPES = dict(
    train_4k=dict(seq=4096, batch=256, kind="train"),
    prefill_32k=dict(seq=32768, batch=32, kind="prefill"),
    decode_32k=dict(seq=32768, batch=128, kind="decode"),
    long_500k=dict(seq=524288, batch=1, kind="decode"),
)


def lm_cells(full_attention: bool = True) -> Tuple[ShapeCell, ...]:
    cells = []
    for name, s in LM_SHAPES.items():
        kind = s["kind"]
        seq, batch = s["seq"], s["batch"]
        if kind == "train":
            def inputs(cfg, seq=seq, batch=batch):
                return dict(tokens=sds((batch, seq), I32),
                            labels=sds((batch, seq), I32))
        elif kind == "prefill":
            def inputs(cfg, seq=seq, batch=batch):
                return dict(tokens=sds((batch, seq), I32))
        else:  # decode: one new token against a seq-long KV cache
            def inputs(cfg, seq=seq, batch=batch):
                return dict(tokens=sds((batch,), I32),
                            cache_len=seq, cache_batch=batch)
        skip = (name == "long_500k" and full_attention)
        note = ("skipped: pure full-attention arch (DESIGN.md) — decode is "
                "O(L)/token but no sub-quadratic variant exists in the "
                "public config" if skip else "")
        cells.append(ShapeCell(name=name, kind=kind, inputs=inputs,
                               skip=skip, note=note))
    return tuple(cells)


# --------------------------------------------------------------- GNN shapes

def _pad512(x: int) -> int:
    """Node/edge counts padded to the 512-chip multi-pod mesh (masked)."""
    return (x + 511) // 512 * 512


GNN_SHAPES = dict(
    full_graph_sm=dict(n=_pad512(2708), e=_pad512(10556), f=1433,
                       kind="train", note="2708 live nodes, rest masked"),
    minibatch_lg=dict(n=262144, e=262144, f=602, kind="train",
                      note="1024 seeds x fanout 15-10 padded subgraph; "
                           "sampler in repro.graph.sampling"),
    ogb_products=dict(n=_pad512(2449029), e=_pad512(61859140), f=100,
                      kind="train", note="2449029 live nodes, rest masked"),
    molecule=dict(n=_pad512(30 * 128), e=64 * 128 * 2, f=32, kind="train",
                  note="128 molecules of 30 nodes, flattened disjoint union"),
)


def gnn_cells(needs_coords: bool, triplet_cap: int = 4) -> Tuple[ShapeCell, ...]:
    cells = []
    for name, s in GNN_SHAPES.items():
        def inputs(cfg, s=s):
            n, e, f = s["n"], s["e"], s["f"]
            d = dict(
                node_feat=sds((n, f), F32),
                senders=sds((e,), I32),
                receivers=sds((e,), I32),
                edge_mask=sds((e,), BOOL),
                node_mask=sds((n,), BOOL),
                labels=sds((n,), I32),
            )
            if needs_coords:
                d["coords"] = sds((n, 3), F32)
                if getattr(cfg, "arch", "") == "dimenet":
                    t = e * triplet_cap
                    d["triplet_kj"] = sds((t,), I32)
                    d["triplet_ji"] = sds((t,), I32)
            return d
        cells.append(ShapeCell(name=name, kind="train", inputs=inputs,
                               note=s.get("note", "")))
    return tuple(cells)


# ------------------------------------------------------------ recsys shapes

RECSYS_SHAPES = dict(
    train_batch=dict(batch=65536, kind="train"),
    serve_p99=dict(batch=512, kind="serve", n_cand=4096),
    serve_bulk=dict(batch=262144, kind="serve", n_cand=4096),
    retrieval_cand=dict(batch=1, kind="serve", n_cand=1_000_000),
)


def recsys_cells() -> Tuple[ShapeCell, ...]:
    cells = []
    for name, s in RECSYS_SHAPES.items():
        if s["kind"] == "train":
            def inputs(cfg, s=s):
                L = cfg.seq_len
                return dict(seq=sds((s["batch"], L), I32),
                            pos=sds((s["batch"], L), I32),
                            neg=sds((s["batch"], L), I32))
        else:
            def inputs(cfg, s=s):
                L = cfg.seq_len
                return dict(seq=sds((s["batch"], L), I32),
                            candidates=sds((s["n_cand"],), I32))
        cells.append(ShapeCell(name=name, kind=s["kind"], inputs=inputs))
    return tuple(cells)


# ----------------------------------------------------- logical sharding rules
#
# Declarative logical-axis layout per parameter leaf, resolved to mesh
# PartitionSpecs by :mod:`repro.dist.sharding`.  A rule maps a leaf NAME (the
# last string key on its tree path) to one logical axis name per TRAILING
# dimension; leading dims (the lax.scan [L] layer stack, MoE [E] experts) are
# padded with None.  Logical names resolve through LOGICAL_TO_MESH, where
# "__fsdp__" stands for the arch's ``fsdp_axes`` (ZeRO-3-style parameter
# sharding over the data axes).  Any placement that does not divide the leaf
# shape on the target mesh is dropped per-dim at resolution time, so the same
# rules serve the 512-chip production meshes and 8-device host tests.

MESH_AXES = ("pod", "data", "model")

LOGICAL_TO_MESH = {
    None: None,
    "batch": ("pod", "data"),
    "embed": "__fsdp__",
    "vocab": ("model",),
    "heads": ("model",),
    "ffn": ("model",),
    "expert": ("model",),
    "hidden": ("model",),
    "items": ("model",),
}

LM_LOGICAL_RULES = {
    # embeddings / unembedding (Megatron vocab-parallel)
    "embed": ("vocab", "embed"),
    "lm_head": ("embed", "vocab"),
    # GQA attention: column-parallel qkv, row-parallel output
    "q_proj": ("embed", "heads"),
    "k_proj": ("embed", "heads"),
    "v_proj": ("embed", "heads"),
    "o_proj": ("heads", "embed"),
    # MLA low-rank path: shard only the per-head expansions
    "q_a": ("embed", None),
    "q_b": (None, "heads"),
    "kv_a": ("embed", None),
    "k_b": (None, "heads"),
    "v_b": (None, "heads"),
    # dense FFN: column-parallel up, row-parallel down
    "w_gate": ("embed", "ffn"),
    "w_up": ("embed", "ffn"),
    "w_down": ("ffn", "embed"),
    "router": (None, None),
}

# Expert-parallel overrides used when the arch is MoE: the [E] expert dim is
# sharded over 'model' (GSPMD then renders dispatch as all-to-alls), so the
# ffn dim must stay unsharded.
MOE_FFN_LOGICAL_RULES = {
    "w_gate": ("expert", "embed", None),
    "w_up": ("expert", "embed", None),
    "w_down": ("expert", None, "embed"),
}

GNN_LOGICAL_RULES = {
    "embed": (None, "hidden"),
    "grid_embed": (None, "hidden"),
    "w_self": (None, "hidden"),
    "w_nbr": (None, "hidden"),
    "w": (None, "hidden"),          # generic MLP layer weight
    "head": (None, None),
    "w_rbf": (None, "hidden"),
}

RECSYS_LOGICAL_RULES = {
    "item_emb": ("items", "embed"),
    "wq": ("embed", "hidden"),
    "wk": ("embed", "hidden"),
    "wv": ("embed", "hidden"),
    "wo": ("hidden", "embed"),
    "ff1": ("embed", "hidden"),
    "ff2": ("hidden", "embed"),
}
