"""graphsage-reddit [gnn]: 2L d_hidden=128 mean agg, fanout 25-10.
[arXiv:1706.02216; paper]"""
from repro.configs.base import ArchSpec, gnn_cells, register
from repro.models.gnn import GNNConfig

ARCH_ID = "graphsage-reddit"


def full_config() -> GNNConfig:
    return GNNConfig(name=ARCH_ID, arch="graphsage", n_layers=2,
                     d_hidden=128, d_in=602, n_classes=41, aggregator="mean")


def smoke_config() -> GNNConfig:
    return GNNConfig(name=ARCH_ID + "-smoke", arch="graphsage", n_layers=2,
                     d_hidden=16, d_in=8, n_classes=4)


SPEC = register(ArchSpec(
    arch_id=ARCH_ID, family="gnn", source="arXiv:1706.02216",
    make_config=full_config, make_smoke_config=smoke_config,
    cells=gnn_cells(needs_coords=False),
    technique_applicable=("YES: summarize the input graph online (MoSSo); "
                          "mean-agg message passing runs on (G*,C) via "
                          "summary_spmm; GetRandomNeighbor doubles as the "
                          "fanout sampler")))
