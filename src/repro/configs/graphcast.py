"""graphcast [gnn]: 16L d_hidden=512 mesh_refinement=6 agg=sum n_vars=227 —
encoder-processor-decoder mesh GNN.  [arXiv:2212.12794; unverified]"""
from repro.configs.base import ArchSpec, gnn_cells, register
from repro.models.gnn import GNNConfig

ARCH_ID = "graphcast"


def full_config() -> GNNConfig:
    return GNNConfig(name=ARCH_ID, arch="graphcast", n_layers=16,
                     d_hidden=512, d_in=227, n_classes=227,
                     n_mesh_frac=4, aggregator="sum")


def smoke_config() -> GNNConfig:
    return GNNConfig(name=ARCH_ID + "-smoke", arch="graphcast", n_layers=2,
                     d_hidden=32, d_in=16, n_classes=8)


SPEC = register(ArchSpec(
    arch_id=ARCH_ID, family="gnn", source="arXiv:2212.12794",
    make_config=full_config, make_smoke_config=smoke_config,
    cells=gnn_cells(needs_coords=False),
    technique_applicable=("partial: summarize-once for the static bipartite "
                          "grid<->mesh graphs; processor mesh gains little")))
