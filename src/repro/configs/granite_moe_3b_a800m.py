"""granite-moe-3b-a800m [moe]: 32L d=1536 24H (kv=8) d_ff=512 vocab=49155,
MoE 40e top-8.  [hf:ibm-granite/granite-3.0-1b-a400m-base; hf]"""
import jax.numpy as jnp
from repro.configs.base import ArchSpec, lm_cells, register
from repro.models.transformer import TransformerConfig

ARCH_ID = "granite-moe-3b-a800m"


def full_config() -> TransformerConfig:
    return TransformerConfig(
        name=ARCH_ID, n_layers=32, d_model=1536, n_heads=24, n_kv_heads=8,
        d_head=64, d_ff=512, vocab=49155, attn="gqa",
        n_experts=40, top_k=8, max_seq=524288)


def smoke_config() -> TransformerConfig:
    return TransformerConfig(
        name=ARCH_ID + "-smoke", n_layers=2, d_model=48, n_heads=6,
        n_kv_heads=2, d_head=8, d_ff=32, vocab=211, attn="gqa",
        n_experts=5, top_k=2, max_seq=128, remat=False,
        param_dtype=jnp.float32, compute_dtype=jnp.float32)


SPEC = register(ArchSpec(
    arch_id=ARCH_ID, family="lm",
    source="hf:ibm-granite/granite-3.0-1b-a400m-base",
    make_config=full_config, make_smoke_config=smoke_config,
    cells=lm_cells(full_attention=True),
    technique_applicable="no (dense LM; exercises MoE/EP substrate)"))
