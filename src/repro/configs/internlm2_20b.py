"""internlm2-20b [dense]: 48L d=6144 48H (kv=8) d_ff=16384 vocab=92544.
[arXiv:2403.17297; hf]"""
import jax.numpy as jnp
from repro.configs.base import ArchSpec, lm_cells, register
from repro.models.transformer import TransformerConfig

ARCH_ID = "internlm2-20b"


def full_config() -> TransformerConfig:
    return TransformerConfig(
        name=ARCH_ID, n_layers=48, d_model=6144, n_heads=48, n_kv_heads=8,
        d_head=128, d_ff=16384, vocab=92544, attn="gqa", max_seq=524288)


def smoke_config() -> TransformerConfig:
    return TransformerConfig(
        name=ARCH_ID + "-smoke", n_layers=2, d_model=64, n_heads=8,
        n_kv_heads=2, d_head=8, d_ff=160, vocab=211, attn="gqa",
        max_seq=128, remat=False,
        param_dtype=jnp.float32, compute_dtype=jnp.float32)


SPEC = register(ArchSpec(
    arch_id=ARCH_ID, family="lm", source="arXiv:2403.17297",
    make_config=full_config, make_smoke_config=smoke_config,
    cells=lm_cells(full_attention=True),
    technique_applicable="no (dense LM)"))
