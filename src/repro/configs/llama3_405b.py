"""llama3-405b [dense]: 126L d=16384 128H (kv=8) d_ff=53248 vocab=128256.
[arXiv:2407.21783; unverified]"""
import jax.numpy as jnp
from repro.configs.base import ArchSpec, lm_cells, register
from repro.models.transformer import TransformerConfig

ARCH_ID = "llama3-405b"


def full_config() -> TransformerConfig:
    return TransformerConfig(
        name=ARCH_ID, n_layers=126, d_model=16384, n_heads=128, n_kv_heads=8,
        d_head=128, d_ff=53248, vocab=128256, attn="gqa", max_seq=524288,
        fsdp_axes=("pod", "data"))  # ZeRO over every DP axis: 405B needs it


def smoke_config() -> TransformerConfig:
    return TransformerConfig(
        name=ARCH_ID + "-smoke", n_layers=2, d_model=64, n_heads=8,
        n_kv_heads=2, d_head=8, d_ff=160, vocab=211, attn="gqa",
        max_seq=128, remat=False,
        param_dtype=jnp.float32, compute_dtype=jnp.float32)


SPEC = register(ArchSpec(
    arch_id=ARCH_ID, family="lm", source="arXiv:2407.21783",
    make_config=full_config, make_smoke_config=smoke_config,
    cells=lm_cells(full_attention=True),
    technique_applicable="no (dense LM; the FSDP/TP stress test)"))
