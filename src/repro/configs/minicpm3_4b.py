"""minicpm3-4b [dense]: 62L d=2560 40H d_ff=6400 vocab=73448 — MLA.
[hf:openbmb/MiniCPM3-4B; hf]"""
import jax.numpy as jnp
from repro.configs.base import ArchSpec, lm_cells, register
from repro.models.transformer import TransformerConfig

ARCH_ID = "minicpm3-4b"


def full_config() -> TransformerConfig:
    return TransformerConfig(
        name=ARCH_ID, n_layers=62, d_model=2560, n_heads=40, n_kv_heads=40,
        d_head=64, d_ff=6400, vocab=73448, attn="mla",
        q_lora=768, kv_lora=256, rope_dim=32, nope_dim=64, v_head_dim=64,
        max_seq=524288)


def smoke_config() -> TransformerConfig:
    return TransformerConfig(
        name=ARCH_ID + "-smoke", n_layers=2, d_model=64, n_heads=4,
        n_kv_heads=4, d_head=16, d_ff=128, vocab=211, attn="mla",
        q_lora=32, kv_lora=24, rope_dim=8, nope_dim=16, v_head_dim=16,
        max_seq=128, remat=False,
        param_dtype=jnp.float32, compute_dtype=jnp.float32)


SPEC = register(ArchSpec(
    arch_id=ARCH_ID, family="lm", source="hf:openbmb/MiniCPM3-4B",
    make_config=full_config, make_smoke_config=smoke_config,
    cells=lm_cells(full_attention=True),
    technique_applicable="no (dense LM; exercises MLA latent-cache serving)"))
