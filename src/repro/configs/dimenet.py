"""dimenet [gnn]: 6 blocks d_hidden=128 n_bilinear=8 n_spherical=7 n_radial=6.
[arXiv:2003.03123; unverified]"""
from repro.configs.base import ArchSpec, gnn_cells, register
from repro.models.gnn import GNNConfig

ARCH_ID = "dimenet"


def full_config() -> GNNConfig:
    return GNNConfig(name=ARCH_ID, arch="dimenet", n_layers=6, d_hidden=128,
                     d_in=32, n_classes=8, n_rbf=6, n_sbf=7, n_bilinear=8)


def smoke_config() -> GNNConfig:
    return GNNConfig(name=ARCH_ID + "-smoke", arch="dimenet", n_layers=2,
                     d_hidden=16, d_in=8, n_classes=4, n_rbf=4, n_sbf=4,
                     n_bilinear=4)


SPEC = register(ArchSpec(
    arch_id=ARCH_ID, family="gnn", source="arXiv:2003.03123",
    make_config=full_config, make_smoke_config=smoke_config,
    cells=gnn_cells(needs_coords=True),
    technique_applicable=("marginal: 30-node radius graphs have near-unique "
                          "neighborhoods (phi/|E| ~ 1); supported, off by "
                          "default. Triplets capped at 4/edge on the large "
                          "non-molecular shapes (DESIGN.md)")))
