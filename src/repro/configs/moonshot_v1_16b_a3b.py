"""moonshot-v1-16b-a3b [moe]: 48L d=2048 16H (kv=16) d_ff=1408 vocab=163840,
MoE 64e top-6.  [hf:moonshotai/Moonlight-16B-A3B; hf]"""
import jax.numpy as jnp
from repro.configs.base import ArchSpec, lm_cells, register
from repro.models.transformer import TransformerConfig

ARCH_ID = "moonshot-v1-16b-a3b"


def full_config() -> TransformerConfig:
    return TransformerConfig(
        name=ARCH_ID, n_layers=48, d_model=2048, n_heads=16, n_kv_heads=16,
        d_head=128, d_ff=1408, vocab=163840, attn="gqa",
        n_experts=64, top_k=6, max_seq=524288)


def smoke_config() -> TransformerConfig:
    return TransformerConfig(
        name=ARCH_ID + "-smoke", n_layers=2, d_model=64, n_heads=4,
        n_kv_heads=4, d_head=16, d_ff=32, vocab=211, attn="gqa",
        n_experts=8, top_k=2, max_seq=128, remat=False,
        param_dtype=jnp.float32, compute_dtype=jnp.float32)


SPEC = register(ArchSpec(
    arch_id=ARCH_ID, family="lm",
    source="hf:moonshotai/Moonlight-16B-A3B",
    make_config=full_config, make_smoke_config=smoke_config,
    cells=lm_cells(full_attention=True),
    technique_applicable="no (dense LM; exercises MoE/EP substrate)"))
