"""sasrec [recsys]: embed_dim=50 2 blocks 1 head seq_len=50, self-attn-seq.
[arXiv:1808.09781; paper]"""
from repro.configs.base import ArchSpec, recsys_cells, register
from repro.models.sasrec import SASRecConfig

ARCH_ID = "sasrec"


def full_config() -> SASRecConfig:
    return SASRecConfig(name=ARCH_ID, n_items=1_000_000, embed_dim=50,
                        n_blocks=2, n_heads=1, seq_len=50)


def smoke_config() -> SASRecConfig:
    return SASRecConfig(name=ARCH_ID + "-smoke", n_items=1000, embed_dim=16,
                        n_blocks=2, n_heads=1, seq_len=12)


SPEC = register(ArchSpec(
    arch_id=ARCH_ID, family="recsys", source="arXiv:1808.09781",
    make_config=full_config, make_smoke_config=smoke_config,
    cells=recsys_cells(),
    technique_applicable=("YES (beyond-paper): the user-item interaction "
                          "stream is a dynamic bipartite graph; MoSSo keeps "
                          "a lossless online summary of it (storage layer)")))
