"""Import all arch configs to populate the registry (side-effectful)."""
from repro.configs.base import REGISTRY, ArchSpec, ShapeCell  # noqa: F401
from repro.configs import (dimenet, egnn, granite_moe_3b_a800m,  # noqa: F401
                           graphcast, graphsage_reddit, internlm2_20b,
                           llama3_405b, minicpm3_4b, mosso_stream,
                           moonshot_v1_16b_a3b, sasrec)

ASSIGNED = [
    "moonshot-v1-16b-a3b", "granite-moe-3b-a800m", "minicpm3-4b",
    "llama3-405b", "internlm2-20b",
    "graphcast", "dimenet", "egnn", "graphsage-reddit",
    "sasrec",
]
