# Paper-table benchmark driver. Prints ``name,us_per_call,derived`` CSV.
#
#   python benchmarks/run.py                  # every benchmark
#   python benchmarks/run.py --only router    # name-filtered subset
#   python benchmarks/run.py --smoke          # tiny CI config: router path
#                                             # (host + device) end to end
#   python benchmarks/run.py --smoke --json BENCH_router.json
#                                             # also write rows as JSON (CI
#                                             # records the perf trajectory;
#                                             # rows carry git sha + config)
#   python benchmarks/run.py --smoke --compare BENCH_router.json
#                                             # exit 1 on >20% us_per_call
#                                             # regression vs the committed
#                                             # baseline (matching rows)
import argparse
import json
import subprocess
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))
sys.path.insert(0, str(Path(__file__).resolve().parent.parent))

REGRESSION_TOLERANCE = 1.20   # --compare fails rows slower than 1.2x baseline


def _git_sha() -> str:
    root = Path(__file__).resolve().parent.parent
    try:
        sha = subprocess.run(
            ["git", "rev-parse", "--short", "HEAD"], cwd=root,
            capture_output=True, text=True, timeout=10).stdout.strip()
        if not sha:
            return "unknown"
        dirty = subprocess.run(
            ["git", "status", "--porcelain"], cwd=root,
            capture_output=True, text=True, timeout=10).stdout.strip()
        return sha + ("-dirty" if dirty else "")
    except OSError:
        return "unknown"


def _run_config(smoke: bool) -> dict:
    import jax
    return {"smoke": smoke, "backend": jax.default_backend(),
            "devices": len(jax.devices())}


_CONFIG_KEYS = ("backend", "devices", "smoke")


def compare_rows(old_rows: list, new_rows: list, tol: float):
    """Regressions: matching rows whose us_per_call grew past tol.

    Rows match on name AND run config (backend/devices/smoke — the
    fields the rows carry precisely so that, e.g., an 8-device baseline
    is never timed against a 1-device run).  Returns ``(regressions,
    skipped)`` where regressions are ``(name, old_us, new_us, ratio)``
    tuples and skipped are names present in both runs that could not be
    compared (config mismatch, or a nonpositive baseline time).  Rows
    missing from either side are ignored — renames must not masquerade
    as wins or losses.

    Caveat (accepted trade-off of gating on absolute wall-clock): the
    baseline is only meaningful on hardware comparable to the machine
    that recorded it; a much slower CI host can trip the tolerance with
    no code change.  Re-record the baseline (``--json`` on a clean
    checkout) when the reference hardware changes.  On noisy reference
    hardware, record the committed baseline as a per-row MAX over a few
    clean-checkout runs (an envelope): run-to-run variance then stays
    inside the tolerance while the regressions this gate exists for
    (compile-in-the-loop, algorithmic blowups — historically 10x+)
    still trip it.
    """
    old = {r["name"]: r for r in old_rows}
    out, skipped = [], []
    for r in new_rows:
        base = old.get(r["name"])
        if base is None:
            continue
        if (base.get("us_per_call", 0) <= 0
                or any(base.get(k) != r.get(k) for k in _CONFIG_KEYS)):
            skipped.append(r["name"])    # matched but not comparable
            continue
        ratio = r["us_per_call"] / base["us_per_call"]
        if ratio > tol:
            out.append((r["name"], base["us_per_call"], r["us_per_call"],
                        ratio))
    return out, skipped


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny-config subset for CI (exercises the stream "
                         "router in all routing/sync/pipeline modes)")
    ap.add_argument("--only", default=None,
                    help="run only benchmarks whose function name contains "
                         "this substring")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="additionally write the rows as a JSON array "
                         "(PR-over-PR perf tracking artifact; each row "
                         "carries the git sha and run config)")
    ap.add_argument("--compare", default=None, metavar="OLD.json",
                    help="compare this run against a baseline JSON and exit "
                         f"nonzero on a >{REGRESSION_TOLERANCE - 1:.0%} "
                         "us_per_call regression for any matching row name")
    args = ap.parse_args()

    from benchmarks import paper_benchmarks as pb
    fns = [pb.smoke] if args.smoke else [
        fn for fn in pb.ALL
        if args.only is None or args.only in fn.__name__]
    if not fns:
        sys.exit(f"no benchmark matches --only {args.only!r}")
    sha, config = _git_sha(), _run_config(args.smoke)
    rows = []
    print("name,us_per_call,derived")
    for fn in fns:
        for (name, us, derived) in fn():
            rows.append({"name": name, "us_per_call": round(us, 1),
                         "derived": derived, "sha": sha, **config})
            print(f"{name},{us:.1f},{derived}")
            sys.stdout.flush()
    if args.json:
        Path(args.json).write_text(json.dumps(rows, indent=2) + "\n")
    if args.compare:
        baseline = Path(args.compare)
        if not baseline.exists():
            sys.exit(f"--compare: baseline {baseline} does not exist — "
                     f"generate and commit one with --json first")
        old_rows = json.loads(baseline.read_text())
        old_sha = old_rows[0].get("sha", "?") if old_rows else "?"
        regressions, skipped = compare_rows(old_rows, rows,
                                            REGRESSION_TOLERANCE)
        matched = {r["name"] for r in rows} & {r["name"] for r in old_rows}
        print(f"compare: {len(matched)} matching rows vs {args.compare} "
              f"(baseline sha {old_sha})")
        for name in skipped:
            print(f"SKIP {name}: run config differs from baseline "
                  f"({'/'.join(_CONFIG_KEYS)}) — not comparable")
        for (name, base, now, ratio) in regressions:
            print(f"REGRESSION {name}: {base:.1f} -> {now:.1f} us_per_call "
                  f"({ratio:.2f}x, tolerance {REGRESSION_TOLERANCE:.2f}x)")
        if regressions:
            sys.exit(1)
        if len(skipped) >= len(matched):
            # a gate that compares nothing must fail loudly, not pass —
            # renamed rows or a config drift would otherwise disarm it
            sys.exit("--compare: no comparable rows (all matched rows "
                     "were renamed or run under a different config)")
        print(f"compare: no regressions "
              f"({len(matched) - len(skipped)} rows within "
              f"{REGRESSION_TOLERANCE:.2f}x)")


if __name__ == '__main__':
    main()
