# One function per paper table. Print ``name,us_per_call,derived`` CSV.
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))
sys.path.insert(0, str(Path(__file__).resolve().parent.parent))


def main() -> None:
    from benchmarks.paper_benchmarks import ALL
    print("name,us_per_call,derived")
    for fn in ALL:
        for (name, us, derived) in fn():
            print(f"{name},{us:.1f},{derived}")
            sys.stdout.flush()


if __name__ == '__main__':
    main()
