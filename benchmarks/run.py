# Paper-table benchmark driver. Prints ``name,us_per_call,derived`` CSV.
#
#   python benchmarks/run.py                  # every benchmark
#   python benchmarks/run.py --only router    # name-filtered subset
#   python benchmarks/run.py --smoke          # tiny CI config: router path
#                                             # (host + device) end to end
#   python benchmarks/run.py --smoke --json BENCH_router.json
#                                             # also write rows as JSON (CI
#                                             # records the perf trajectory)
import argparse
import json
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))
sys.path.insert(0, str(Path(__file__).resolve().parent.parent))


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny-config subset for CI (exercises the stream "
                         "router in both routing modes)")
    ap.add_argument("--only", default=None,
                    help="run only benchmarks whose function name contains "
                         "this substring")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="additionally write the rows as a JSON array "
                         "(PR-over-PR perf tracking artifact)")
    args = ap.parse_args()

    from benchmarks import paper_benchmarks as pb
    fns = [pb.smoke] if args.smoke else [
        fn for fn in pb.ALL
        if args.only is None or args.only in fn.__name__]
    if not fns:
        sys.exit(f"no benchmark matches --only {args.only!r}")
    rows = []
    print("name,us_per_call,derived")
    for fn in fns:
        for (name, us, derived) in fn():
            rows.append({"name": name, "us_per_call": round(us, 1),
                         "derived": derived})
            print(f"{name},{us:.1f},{derived}")
            sys.stdout.flush()
    if args.json:
        Path(args.json).write_text(json.dumps(rows, indent=2) + "\n")


if __name__ == '__main__':
    main()
