# Paper-table benchmark driver. Prints ``name,us_per_call,derived`` CSV.
#
#   python benchmarks/run.py                  # every benchmark
#   python benchmarks/run.py --only router    # name-filtered subset
#   python benchmarks/run.py --smoke          # tiny CI config: router path
#                                             # (host + device) end to end
import argparse
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))
sys.path.insert(0, str(Path(__file__).resolve().parent.parent))


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny-config subset for CI (exercises the stream "
                         "router in both routing modes)")
    ap.add_argument("--only", default=None,
                    help="run only benchmarks whose function name contains "
                         "this substring")
    args = ap.parse_args()

    from benchmarks import paper_benchmarks as pb
    fns = [pb.smoke] if args.smoke else [
        fn for fn in pb.ALL
        if args.only is None or args.only in fn.__name__]
    if not fns:
        sys.exit(f"no benchmark matches --only {args.only!r}")
    print("name,us_per_call,derived")
    for fn in fns:
        for (name, us, derived) in fn():
            print(f"{name},{us:.1f},{derived}")
            sys.stdout.flush()


if __name__ == '__main__':
    main()
