"""One benchmark per paper table/figure (MoSSo, KDD 2020).

Each function returns a list of CSV rows (name, us_per_call, derived).
Scales are sized for a single CPU core; the shapes of the curves — not the
absolute magnitudes — are what reproduce the paper's claims (EXPERIMENTS.md
maps each one to its figure).
"""
from __future__ import annotations

import time
from typing import List, Tuple

from repro.core.engine import (BatchedSummarizer, EngineConfig,
                               ShardedSummarizer)
from repro.core.reference import ALGORITHMS, MoSSo, MoSSoSimple
from repro.graph.streams import (barabasi_albert_edges, copying_model_edges,
                                 edges_to_fully_dynamic_stream,
                                 edges_to_insertion_stream)

Row = Tuple[str, float, str]


def _stream(n_nodes=800, deg=4, seed=0, fully_dynamic=True):
    edges = barabasi_albert_edges(n_nodes, deg, seed)
    if fully_dynamic:
        return edges_to_fully_dynamic_stream(edges, seed=seed)
    return edges_to_insertion_stream(edges, seed=seed)


def fig4_speed() -> List[Row]:
    """Fig. 4: per-change time, streaming algorithms vs batch re-run."""
    rows: List[Row] = []
    stream = _stream(700, 4, seed=1)
    per_change = {}
    for name in ("mosso", "simple", "greedy", "mcmc"):
        sub = stream if name in ("mosso", "simple") else stream[:1200]
        algo = ALGORITHMS[name](seed=0)
        if hasattr(algo, "c"):
            algo.c = 40
        t0 = time.time()
        algo.run(sub)
        us = 1e6 * (time.time() - t0) / len(sub)
        per_change[name] = us
        rows.append((f"fig4/{name}", us,
                     f"ratio={algo.s.compression_ratio():.3f}"))
    # batch baseline: reflecting one change requires a full from-scratch
    # rerun (Sect. 1/Table 1) — measure one full pass as its per-change cost
    t0 = time.time()
    batch = MoSSo(seed=0, c=40)
    batch.run(edges_to_insertion_stream(
        sorted({(min(u, v), max(u, v)) for (u, v, i) in stream if i}), seed=2))
    batch_us = 1e6 * (time.time() - t0)
    rows.append(("fig4/batch-rerun", batch_us,
                 f"speedup_vs_mosso={batch_us/per_change['mosso']:.0f}x"))
    return rows


def fig5_compression() -> List[Row]:
    """Fig. 5: any-time compression ratio over the stream."""
    rows: List[Row] = []
    edges = copying_model_edges(900, 5, 0.75, seed=2)
    stream = edges_to_fully_dynamic_stream(edges, seed=3)
    for name in ("mosso", "simple", "mcmc", "greedy"):
        sub = stream if name in ("mosso", "simple") else stream[:1200]
        algo = ALGORITHMS[name](seed=1)
        if hasattr(algo, "c"):
            algo.c = 40
        t0 = time.time()
        stats = algo.run(sub, record_every=max(1, len(sub) // 5))
        us = 1e6 * (time.time() - t0) / len(sub)
        hist = ";".join(f"{t}:{p/max(e,1):.3f}" for (t, p, e)
                        in stats.phi_history)
        rows.append((f"fig5/{name}", us,
                     f"final={algo.s.compression_ratio():.3f} hist={hist}"))
    return rows


def fig1c_scalability() -> List[Row]:
    """Fig. 1c / 7b,c: accumulated runtime vs #changes (near-linearity)."""
    import math
    rows: List[Row] = []
    for name, cls in (("mosso", MoSSo), ("simple", MoSSoSimple)):
        xs, ys = [], []
        for n in (200, 400, 800, 1600):
            stream = _stream(n, 4, seed=4, fully_dynamic=False)
            algo = cls(seed=0, c=30)
            t0 = time.time()
            algo.run(stream)
            el = time.time() - t0
            xs.append(math.log(len(stream)))
            ys.append(math.log(max(el, 1e-6)))
        n_ = len(xs)
        slope = ((n_ * sum(x * y for x, y in zip(xs, ys))
                  - sum(xs) * sum(ys))
                 / (n_ * sum(x * x for x in xs) - sum(xs) ** 2))
        rows.append((f"fig1c/{name}", 1e6 * math.exp(ys[-1]) / 1600,
                     f"runtime_exponent={slope:.2f} (1.0 = linear)"))
    return rows


def fig6_parameters() -> List[Row]:
    """Fig. 6: effect of escape prob e and sample count c."""
    rows: List[Row] = []
    edges = copying_model_edges(500, 5, 0.8, seed=5)
    stream = edges_to_insertion_stream(edges, seed=5)
    for e in (0.0, 0.1, 0.3, 0.5):
        algo = MoSSo(seed=2, c=40, escape=e)
        t0 = time.time()
        algo.run(stream)
        rows.append((f"fig6a/e={e}", 1e6 * (time.time() - t0) / len(stream),
                     f"ratio={algo.s.compression_ratio():.3f}"))
    for c in (10, 40, 120):
        algo = MoSSo(seed=2, c=c, escape=0.1)
        t0 = time.time()
        algo.run(stream)
        rows.append((f"fig6b/c={c}", 1e6 * (time.time() - t0) / len(stream),
                     f"ratio={algo.s.compression_ratio():.3f}"))
    return rows


def fig7a_graph_properties() -> List[Row]:
    """Fig. 7a: higher copying probability beta -> better compression."""
    rows: List[Row] = []
    for beta in (0.1, 0.3, 0.5, 0.7, 0.9):
        edges = copying_model_edges(600, 5, beta, seed=6)
        stream = edges_to_insertion_stream(edges, seed=6)
        algo = MoSSo(seed=3, c=40, escape=0.1)
        t0 = time.time()
        algo.run(stream)
        rows.append((f"fig7a/beta={beta}",
                     1e6 * (time.time() - t0) / len(stream),
                     f"ratio={algo.s.compression_ratio():.3f}"))
    return rows


def engine_throughput() -> List[Row]:
    """Beyond-paper: Tier-B batched engine vs Tier-A reference throughput."""
    rows: List[Row] = []
    stream = _stream(900, 4, seed=7)
    cfg = EngineConfig(n_cap=2048, m_cap=1 << 14, d_cap=64, sn_cap=48,
                       c=24, batch=64, escape=0.2)
    bs = BatchedSummarizer(cfg)
    bs.process(stream[:cfg.batch])           # compile outside the clock
    t0 = time.time()
    bs.process(stream[cfg.batch:])
    us_b = 1e6 * (time.time() - t0) / (len(stream) - cfg.batch)
    rows.append(("engine/batched", us_b,
                 f"ratio={bs.compression_ratio():.3f} {bs.stats()}"))
    ref = MoSSo(seed=0, c=24, escape=0.2)
    t0 = time.time()
    ref.run(stream)
    us_r = 1e6 * (time.time() - t0) / len(stream)
    rows.append(("engine/reference", us_r,
                 f"ratio={ref.s.compression_ratio():.3f} "
                 f"speedup={us_r/max(us_b,1e-9):.1f}x"))
    return rows


def router_throughput(n_nodes: int = 700, deg: int = 4, n_shards: int = 2,
                      chunk: int = 512) -> List[Row]:
    """Beyond-paper: sharded stream throughput across routing/sync modes.

    Six configurations run the same shards over the same FD stream with
    the same chunk boundaries (so their engines are in lockstep — equal phi
    is part of the measurement's sanity check):

    * ``device`` — the default pipelined sync-free router: hash-based
      placement (zero host dict ops), delivery statically guaranteed by
      the drain budget (zero per-chunk host fetches), chunk k+1's route
      stage dispatched while chunk k's engine stage runs, and the shard
      replicas stacked per device batched as ONE vmapped engine program.
    * ``device-vmapped`` — ``replica_exec="vmap"`` pinned explicitly (the
      default today; the row stays meaningful if the default ever moves).
    * ``device-vmapped-pallas`` — the vmapped replica layout with the
      batched probes fused into the Pallas kernel
      (``trial_backend="pallas"``; interpret mode on CPU, where the
      kernel inlines into the XLA program — the row tracks how the
      accelerator-native layout fares with the while-dispatch count cut).
    * ``device-map`` — ``replica_exec="map"``: replicas serialized per
      device by ``lax.map``, the replica-layout differential reference;
      the delta against ``device-vmapped`` is the replica-parallelism win.
    * ``device-serial`` — the same two stages dispatched back to back per
      chunk; the delta against ``device`` is the pure pipeline win.
    * ``device-synced`` — ``chunk_sync=True``, i.e. the PR-2 behavior of
      fetching the overflow watermark every chunk; the delta against
      ``device-serial`` is the pure sync-elision win.
    * ``host`` — host-side bucketing, the differential reference.

    Warmup note: each mode's step compiles TWICE (the first call sees
    uncommitted host arrays, the second sees the device-sharded outputs
    fed back in), so two chunks run before the clock starts — the PR-3
    benchmark warmed only one and timed the second compile in whichever
    mode ran first (its committed ``device`` row reads 45.8ms/change
    against a ~1.6ms steady state).
    """
    rows: List[Row] = []
    stream = _stream(n_nodes, deg, seed=9)
    cfg = EngineConfig(n_cap=2048, m_cap=1 << 14, d_cap=64, sn_cap=48,
                       c=16, batch=64, escape=0.2)
    modes = (("device", dict(routing="device")),
             ("device-vmapped", dict(routing="device", replica_exec="vmap")),
             ("device-vmapped-pallas",
              dict(routing="device", replica_exec="vmap",
                   trial_backend="pallas")),
             ("device-map", dict(routing="device", replica_exec="map")),
             ("device-serial", dict(routing="device", pipeline=False)),
             ("device-synced", dict(routing="device", chunk_sync=True)),
             ("host", dict(routing="host")))
    warm = 2 * chunk
    us, phis, overflows = {}, {}, {}
    for name, kw in modes:
        ss = ShardedSummarizer(cfg, n_shards=n_shards, router_chunk=chunk,
                               **kw)
        if name == "device":
            assert ss.sync_free, "default geometry must elide the sync"
            assert ss.pipeline, "default dispatch must pipeline"
        if name == "device-synced":
            assert not ss.sync_free
        for off in (0, chunk):               # compile outside the clock
            ss.process(stream[off:off + chunk])
            _ = ss.phi
        t0 = time.time()
        ss.process(stream[warm:])
        _ = ss.phi                           # sync before stopping the clock
        us[name] = 1e6 * (time.time() - t0) / max(len(stream) - warm, 1)
        phis[name] = ss.phi
        st = ss.stats()
        overflows[name] = ss.router_overflows
        if name == "device":
            # the steady-state contract this benchmark certifies: no
            # per-chunk host fetches and no per-chunk host dict ops
            assert st["router_syncs"] == 0, st
            assert st["router_host_dict_ops"] == 0, st
        rows.append((f"router/{name}", us[name],
                     f"phi={ss.phi} ratio={ss.compression_ratio():.3f} "
                     f"shards={n_shards} "
                     f"overflows={ss.router_overflows} "
                     f"drain_rounds={st['router_drain_rounds']} "
                     f"syncs={ss.router_syncs} "
                     f"dict_ops={st['router_host_dict_ops']}"))
    # lockstep sanity: only guaranteed when no host fallback ran (a
    # fallback legitimately changes the PRNG schedule)
    assert overflows["device-synced"] or len(set(phis.values())) == 1, phis
    rows.append(("router/replica_vmap_gain", us["device-vmapped"],
                 f"map_over_vmapped="
                 f"{us['device-map']/max(us['device-vmapped'],1e-9):.2f}x"))
    rows.append(("router/probe_kernel_gain", us["device-vmapped-pallas"],
                 f"xla_over_pallas="
                 f"{us['device-vmapped']/max(us['device-vmapped-pallas'],1e-9):.2f}x"))
    rows.append(("router/pipeline_gain", us["device"],
                 f"serial_over_pipelined="
                 f"{us['device-serial']/max(us['device'],1e-9):.2f}x"))
    rows.append(("router/sync_elision", us["device"],
                 f"synced_over_elided="
                 f"{us['device-synced']/max(us['device'],1e-9):.2f}x"))
    rows.append(("router/speedup", us["device"],
                 f"host_over_device={us['host']/max(us['device'],1e-9):.2f}x"))
    return rows


def probe_microbench(cap: int = 4096, batch: int = 256,
                     iters: int = 200) -> List[Row]:
    """Beyond-paper: the trial step's dominant inner loop in isolation.

    One batch of ``ht_find`` probes against a loaded table, measured as
    (a) the XLA lowering (vmapped ``lax.while_loop``, one batched while
    dispatch per call — the per-trial shape every trial phase and the
    intern pre-lookup pays today) vs (b) one fused Pallas probe-kernel
    launch (interpret mode on CPU, where the kernel body inlines into the
    XLA program — the row tracks the *dispatch-count* delta; the compiled
    kernel's arithmetic win only shows on an accelerator backend).
    Both paths run under jit on identical inputs; bitwise agreement is
    asserted before the clock starts.
    """
    import numpy as np

    import jax
    import jax.numpy as jnp
    from repro.core.engine.hashtable import (ht_lookup_batch, ht_new,
                                             ht_set, trial_backend_scope)

    rng = np.random.default_rng(0)
    ht = ht_new(cap)
    keys = np.unique(
        rng.integers(0, 8 * cap, size=(cap // 2, 2)).astype(np.int32),
        axis=0)
    for i, (a, b) in enumerate(keys):
        ht = ht_set(ht, int(a), int(b), i + 1)
    q = np.concatenate([keys[:batch // 2],
                        rng.integers(0, 8 * cap, size=(batch // 2, 2))
                        ]).astype(np.int32)
    q1, q2 = jnp.asarray(q[:, 0]), jnp.asarray(q[:, 1])

    def make(backend):
        @jax.jit
        def f(t, a, b):
            with trial_backend_scope(backend):
                return ht_lookup_batch(t, a, b, default=-1)
        return f

    fns = {f"probe/{n}": make(n) for n in ("xla", "pallas")}
    outs = {n: f(ht, q1, q2).block_until_ready() for n, f in fns.items()}
    assert (np.asarray(outs["probe/xla"])
            == np.asarray(outs["probe/pallas"])).all(), "probe drift"

    rows: List[Row] = []
    us = {}
    for name, f in fns.items():
        t0 = time.time()
        for _ in range(iters):
            out = f(ht, q1, q2)
        out.block_until_ready()
        us[name] = 1e6 * (time.time() - t0) / iters
        rows.append((name, us[name], f"cap={cap} batch={batch}"))
    rows.append(("probe/kernel_gain", us["probe/pallas"],
                 f"xla_over_pallas="
                 f"{us['probe/xla']/max(us['probe/pallas'],1e-9):.2f}x"))
    return rows


def query_microbench(n_nodes: int = 300, deg: int = 4, n_shards: int = 2,
                     chunk: int = 256, batch_q: int = 256,
                     iters: int = 20) -> List[Row]:
    """Beyond-paper: serving reads from the live summary (serve/query.py).

    A sharded summarizer ingests an FD stream, then the online query path
    answers reads from flush-epoch snapshots without decompression:

    * ``query/point`` — one single-label service round trip
      (``neighbors`` + ``degree`` + ``has_edge``), us per operation; the
      end-to-end latency a point read pays, host label translation and
      snapshot fan-out included.
    * ``query/batch`` — a ``batch_q``-label ``neighbors_batch`` +
      ``degree_batch`` sweep, us per query; the amortized shape GNN-style
      gathers use (examples/gnn_over_summary.py).

    Correctness is asserted against ``decode_edges()`` before the clock
    starts — the same query-vs-decode bar tests/test_differential.py
    holds the kernels to.
    """
    import numpy as np

    rows: List[Row] = []
    stream = _stream(n_nodes, deg, seed=13)
    cfg = EngineConfig(n_cap=2048, m_cap=1 << 14, d_cap=64, sn_cap=48,
                       c=16, batch=64, escape=0.2)
    ss = ShardedSummarizer(cfg, n_shards=n_shards, router_chunk=chunk)
    ss.run(stream)
    ss.flush()

    # query-vs-decode agreement before anything is timed
    dec = ss.materialize().decode_edges()
    adj: dict = {}
    for (u, v) in dec:
        adj.setdefault(u, set()).add(v)
        adj.setdefault(v, set()).add(u)
    view = ss.query()
    labs = view.seen_labels()
    check = labs[:32]
    assert view.neighbors_batch(check) == \
        [adj.get(x, set()) for x in check], "query drift vs decode"

    rng = np.random.default_rng(0)
    qlabs = [labs[i] for i in rng.integers(0, len(labs), batch_q)]
    pairs = list(zip(qlabs, qlabs[::-1]))

    # warm both kernel shapes (point + batch) outside the clock
    view.neighbors(qlabs[0])
    view.degree(qlabs[0])
    view.has_edge(*pairs[0])
    view.neighbors_batch(qlabs)
    view.degree_batch(qlabs)

    n_pt = 16
    t0 = time.time()
    for _ in range(iters):
        for lab, pair in zip(qlabs[:n_pt], pairs[:n_pt]):
            view.neighbors(lab)
            view.degree(lab)
            if pair[0] != pair[1]:
                view.has_edge(*pair)
    us_pt = 1e6 * (time.time() - t0) / (iters * n_pt * 3)
    rows.append(("query/point", us_pt,
                 f"n={n_nodes} shards={n_shards} ops=neighbors+degree+"
                 f"has_edge phi={ss.phi}"))

    t0 = time.time()
    for _ in range(iters):
        view.neighbors_batch(qlabs)
        view.degree_batch(qlabs)
    us_b = 1e6 * (time.time() - t0) / (iters * 2 * batch_q)
    rows.append(("query/batch", us_b,
                 f"batch={batch_q} n={n_nodes} shards={n_shards} "
                 f"point_over_batch={us_pt/max(us_b,1e-9):.1f}x"))
    return rows


def policy_summary(n_nodes: int = 400, deg: int = 4) -> List[Row]:
    """Beyond-paper (PR 8): per-policy compression/throughput of the
    batched engine on one FD stream.

    One row per proposal x objective pair, named ``summary/ratio-<triple>``
    so the committed ``BENCH_router.json`` baseline gates BOTH directions
    through ``run.py --compare``: a policy whose step got slower trips the
    us_per_call tolerance, and the achieved compression ratio rides in the
    derived column for PR-over-PR eyeballing (ratios are seeded-stream
    deterministic, not a tolerance gate).  The weighted rows price
    corrections by hashed node weights (``weight_levels=3``), so their phi
    is the weighted objective — comparable release over release, not
    against the exact rows.
    """
    rows: List[Row] = []
    stream = _stream(n_nodes, deg, seed=11)
    for prop in ("minhash", "magsdm"):
        for obj, levels in (("exact", 0), ("weighted", 3)):
            cfg = EngineConfig(n_cap=2048, m_cap=1 << 14, d_cap=64,
                               sn_cap=48, c=24, batch=64, escape=0.2,
                               proposal=prop, objective=obj,
                               weight_levels=levels)
            bs = BatchedSummarizer(cfg)
            bs.process(stream[:cfg.batch])   # compile outside the clock
            t0 = time.time()
            bs.process(stream[cfg.batch:])
            _ = bs.phi                       # sync before stopping the clock
            us = 1e6 * (time.time() - t0) / (len(stream) - cfg.batch)
            rows.append((f"summary/ratio-{prop}-{obj}", us,
                         f"ratio={bs.compression_ratio():.3f} phi={bs.phi} "
                         f"edges={bs.num_edges}"))
    return rows


def ckpt_microbench(n_nodes: int = 300, deg: int = 4, n_shards: int = 2,
                    chunk: int = 256, iters: int = 5) -> List[Row]:
    """Beyond-paper (PR 9): price of the crash-consistency layer.

    A sharded summarizer ingests an FD stream with write-ahead journaling
    on, then the two recovery primitives are timed in isolation:

    * ``ckpt/save`` — one epoch checkpoint of the full recovery closure
      (flush + state/intern fetch + atomic fsynced write + retention +
      journal compaction), us per call; what a ``--checkpoint-every``
      epoch costs the stream.
    * ``ckpt/restore`` — restore into a FRESH summarizer (checksum verify
      + array load + host closure unpickle), us per call; the floor of
      the recovery path (journal replay rides on normal dispatch and is
      priced by the router rows).

    A bitwise restore check runs before the clock starts — the same bar
    tests/test_recovery.py holds the layer to."""
    import shutil
    import tempfile

    import jax
    import numpy as np

    rows: List[Row] = []
    stream = _stream(n_nodes, deg, seed=13)
    cfg = EngineConfig(n_cap=2048, m_cap=1 << 14, d_cap=64, sn_cap=48,
                       c=16, batch=64, escape=0.2)
    d = tempfile.mkdtemp(prefix="mosso_ckpt_bench_")
    try:
        ss = ShardedSummarizer(cfg, n_shards=n_shards, router_chunk=chunk,
                               checkpoint_dir=d)
        ss.run(stream)

        # restored == saved, leaf-bitwise, before anything is timed
        ss.save()
        fresh = ShardedSummarizer(cfg, n_shards=n_shards,
                                  router_chunk=chunk, checkpoint_dir=d)
        fresh.restore()
        for a, b in zip(jax.tree.leaves(ss._ckpt_tree()),
                        jax.tree.leaves(fresh._ckpt_tree())):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

        t0 = time.time()
        for _ in range(iters):
            ss.save()
        us_save = 1e6 * (time.time() - t0) / iters
        rows.append(("ckpt/save", us_save,
                     f"n={n_nodes} shards={n_shards} phi={ss.phi} "
                     f"fsync+checksum epoch checkpoint"))

        t0 = time.time()
        for _ in range(iters):
            fresh.restore()
        us_rst = 1e6 * (time.time() - t0) / iters
        rows.append(("ckpt/restore", us_rst,
                     f"n={n_nodes} shards={n_shards} verify+load, "
                     f"save_over_restore={us_save/max(us_rst,1e-9):.1f}x"))
    finally:
        shutil.rmtree(d, ignore_errors=True)
    return rows


def smoke() -> List[Row]:
    """Tiny-config subset for CI: exercises both routing modes end to end
    (including the lockstep phi assertion), the probe microbenchmark, the
    online query path, the per-policy summary rows, and the checkpoint
    save/restore primitives in well under a minute."""
    return (router_throughput(n_nodes=120, deg=3, n_shards=2, chunk=128)
            + probe_microbench(cap=1024, batch=128, iters=50)
            + query_microbench(n_nodes=120, deg=3, n_shards=2, chunk=128,
                               batch_q=64, iters=5)
            + policy_summary(n_nodes=120, deg=3)
            + ckpt_microbench(n_nodes=120, deg=3, n_shards=2, chunk=128,
                              iters=3))


ALL = [fig4_speed, fig5_compression, fig1c_scalability, fig6_parameters,
       fig7a_graph_properties, engine_throughput, router_throughput,
       probe_microbench, query_microbench, policy_summary, ckpt_microbench]
