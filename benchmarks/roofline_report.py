"""Render the roofline table (EXPERIMENTS.md §Roofline) from dry-run JSONs.

Per (arch x shape x mesh): the three roofline terms, the dominant one,
MODEL_FLOPS = 6·N(_active)·D vs compiled HLO FLOPs, and a one-line lever.

Usage:  PYTHONPATH=src python -m benchmarks.roofline_report [--pod2]
"""
from __future__ import annotations

import glob
import json
import os
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

RESULTS = os.path.join(os.path.dirname(__file__), "results", "dryrun")

# active param counts (per token) and dense param counts, computed from the
# exact configs (scripts/param_counts.py); used for MODEL_FLOPS = 6·N·D.
PARAMS_ACTIVE = {}


def _param_counts():
    import jax
    from repro.configs import REGISTRY
    from repro.models import transformer as tfm
    from repro.models.common import param_count
    out = {}
    for arch, spec in REGISTRY.items():
        if spec.family != "lm":
            continue
        cfg = spec.make_config()
        p = jax.eval_shape(lambda k: tfm.init_transformer(cfg, k),
                           jax.random.key(0))
        total = param_count(p)
        if cfg.moe:
            lp = p["layers"]
            expert = sum(int(x.size) for name, x in lp.items()
                         if name.startswith("w_"))
            active = total - expert + int(expert * cfg.top_k / cfg.n_experts)
        else:
            active = total
        out[arch] = (total, active)
    return out


LEVERS = {
    "compute": "raise MXU utilization (larger tiles / fewer small ops)",
    "memory": "cut bytes: less remat recompute, fuse elementwise, bf16 "
              "activations",
    "collective": "reshard to localize gathers (see §Perf), overlap "
                  "collectives with compute",
}


def load(pod2: bool):
    suffix = "pod2" if pod2 else "pod1"
    rows = []
    for f in sorted(glob.glob(os.path.join(RESULTS, f"*__{suffix}.json"))):
        rows.append(json.load(open(f)))
    return rows


def tokens_for(arch: str, shape: str) -> int:
    from repro.configs.base import LM_SHAPES
    s = LM_SHAPES.get(shape)
    if s is None:
        return 0
    if shape.startswith(("decode", "long")):
        return s["batch"]          # one token per sequence per step
    return s["batch"] * s["seq"]


def main() -> None:
    pod2 = "--pod2" in sys.argv
    counts = _param_counts()
    from repro.configs import REGISTRY
    from repro.launch.steps import TRAIN_OVERRIDES
    rows = load(pod2)
    hdr = ("| arch | shape | dominant | t_comp (s) | t_mem (s) | t_coll (s) "
           "| xSCAN | step t_comp | HLO TFLOP/dev | model/HLO | lever |")
    print("Raw terms are per-scan-body (LM cells scan over layers/micro); "
          "xSCAN is the static trip product, 'step t_comp' = t_comp*xSCAN.")
    print()
    print(hdr)
    print("|" + "---|" * 11)
    for r in rows:
        if r.get("status") != "ok":
            print(f"| {r['arch']} | {r['shape']} | *{r.get('status')}* "
                  f"| - | - | - | - | - | - | - | {r.get('note', '')[:55]} |")
            continue
        t = r["roofline"]
        spec = REGISTRY.get(r["arch"])
        trips = 1
        if spec is not None and spec.family == "lm":
            cfg = spec.make_config()
            trips = cfg.n_layers
            if r["kind"] == "train":
                trips *= TRAIN_OVERRIDES.get(r["arch"], {}).get(
                    "n_microbatches", 1)
        ratio = ""
        if r["arch"] in counts and r["kind"] in ("train", "prefill", "decode"):
            total, active = counts[r["arch"]]
            tok = tokens_for(r["arch"], r["shape"])
            if tok:
                mf = (6 if r["kind"] == "train" else 2) * active * tok
                hlo_global = t["flops"] * trips * t["n_chips"]
                if hlo_global > 0:
                    ratio = f"{mf / hlo_global:.2f}"
        print(f"| {r['arch']} | {r['shape']} | **{t['dominant']}** "
              f"| {t['t_compute']:.2e} | {t['t_memory']:.2e} "
              f"| {t['t_collective']:.2e} | {trips} "
              f"| {t['t_compute']*trips:.2e} | {t['flops']/1e12:.2f} "
              f"| {ratio} | {LEVERS[t['dominant']][:40]} |")


if __name__ == "__main__":
    main()
